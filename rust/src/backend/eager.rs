//! Eager reference backend: executes a captured graph with the CPU tensor
//! library. This is the correctness oracle for the XLA backend and the
//! executor the debugger steps through (`on_node` callback maps to dump
//! lines).
//!
//! The hot path is [`ExecPlan`]: a per-graph execution plan computed once
//! at compile time — constants pre-materialized into an env template, op
//! steps laid out in order, last-use (liveness) lists so intermediate
//! buffers are released as soon as possible, and a reusable slot arena so
//! steady-state calls do no per-call planning work and no env reallocation.

use std::cell::RefCell;
use std::rc::Rc;

use crate::api::{CompiledModule, DepyfError};
use crate::graph::{Graph, NodeId, NodeKind, OpKind};
use crate::tensor::{self, Tensor};

/// Evaluate one op node against the environment. Shared by the planned and
/// traced executors. Tensor-library failures surface as typed
/// [`DepyfError::Tensor`] (shape vs axis vs index), not strings.
fn eval_op(g: &Graph, id: usize, env: &[Option<Tensor>]) -> Result<Tensor, DepyfError> {
    let (op, args) = match &g.nodes[id].kind {
        NodeKind::Op(op, args) => (op, args),
        _ => return Err(DepyfError::Backend(format!("node {} is not an op", id))),
    };
    let get = |i: usize| -> Result<&Tensor, DepyfError> {
        env[args[i]]
            .as_ref()
            .ok_or_else(|| DepyfError::Backend(format!("node {} uses unevaluated node {}", id, args[i])))
    };
    Ok(match op {
        OpKind::Add => tensor::add(get(0)?, get(1)?)?,
        OpKind::Sub => tensor::sub(get(0)?, get(1)?)?,
        OpKind::Mul => tensor::mul(get(0)?, get(1)?)?,
        OpKind::Div => tensor::div(get(0)?, get(1)?)?,
        OpKind::Pow => tensor::pow(get(0)?, get(1)?)?,
        OpKind::Maximum => tensor::maximum(get(0)?, get(1)?)?,
        OpKind::Minimum => tensor::minimum(get(0)?, get(1)?)?,
        OpKind::Neg => tensor::neg(get(0)?),
        OpKind::Relu => tensor::relu(get(0)?),
        OpKind::Gelu => tensor::gelu(get(0)?),
        OpKind::Tanh => tensor::tanh(get(0)?),
        OpKind::Sigmoid => tensor::sigmoid(get(0)?),
        OpKind::Exp => tensor::exp(get(0)?),
        OpKind::Log => tensor::log(get(0)?),
        OpKind::Sqrt => tensor::sqrt(get(0)?),
        OpKind::Abs => tensor::abs(get(0)?),
        OpKind::MatMul => tensor::matmul(get(0)?, get(1)?)?,
        OpKind::Transpose => tensor::transpose(get(0)?)?,
        OpKind::Reshape(spec) => {
            let t = get(0)?;
            let shape = tensor::reshape_infer(t.numel(), spec)?;
            t.reshape(shape)
        }
        OpKind::Permute(perm) => tensor::permute(get(0)?, perm)?,
        OpKind::Softmax => tensor::softmax(get(0)?)?,
        OpKind::Sum(ax) => tensor::sum(get(0)?, *ax)?,
        OpKind::Mean(ax) => tensor::mean(get(0)?, *ax)?,
        OpKind::Max(ax) => tensor::max_reduce(get(0)?, *ax)?,
        OpKind::Min(ax) => tensor::min_reduce(get(0)?, *ax)?,
        OpKind::LayerNorm => tensor::layernorm(get(0)?, get(1)?, get(2)?, 1e-5)?,
        OpKind::Embedding => tensor::embedding(get(0)?, get(1)?)?,
        OpKind::CrossEntropy => tensor::cross_entropy(get(0)?, get(1)?)?,
    })
}

/// A per-graph execution plan: everything derivable from the graph alone,
/// computed once when the backend compiles it instead of on every call.
pub struct ExecPlan {
    graph: Rc<Graph>,
    /// Env template with constants pre-materialized (`ConstScalar` /
    /// `ConstTensor` nodes); tensors share storage via `Rc`, so cloning
    /// the template per call is pointer-cheap.
    template: Vec<Option<Tensor>>,
    /// Op node ids in execution order (graph nodes are topologically
    /// ordered by construction; placeholders and constants are skipped).
    steps: Vec<NodeId>,
    /// Parallel to `steps`: env slots whose value dies after that step
    /// (not used by any later step and not a graph output). Freed eagerly
    /// so peak memory is bounded by live values, not graph size.
    dead_after: Vec<Vec<NodeId>>,
    /// Reused env buffer — steady-state calls reallocate nothing.
    arena: RefCell<Vec<Option<Tensor>>>,
}

impl ExecPlan {
    pub fn new(graph: Rc<Graph>) -> ExecPlan {
        let mut template: Vec<Option<Tensor>> = vec![None; graph.nodes.len()];
        let mut steps = Vec::new();
        for (id, node) in graph.nodes.iter().enumerate() {
            match &node.kind {
                NodeKind::Placeholder { .. } => {}
                NodeKind::ConstScalar(v) => template[id] = Some(Tensor::scalar(*v as f32)),
                NodeKind::ConstTensor(t) => template[id] = Some(t.clone()),
                NodeKind::Op(..) => steps.push(id),
            }
        }
        // Liveness: a slot dies after the last step that reads it, unless
        // it is a graph output (outputs stay live through collection).
        let mut last_use: Vec<Option<usize>> = vec![None; graph.nodes.len()];
        for (si, &id) in steps.iter().enumerate() {
            if let NodeKind::Op(_, args) = &graph.nodes[id].kind {
                for &a in args {
                    last_use[a] = Some(si);
                }
            }
        }
        let mut dead_after: Vec<Vec<NodeId>> = vec![Vec::new(); steps.len()];
        for (node, lu) in last_use.iter().enumerate() {
            if let Some(si) = lu {
                if !graph.outputs.contains(&node) {
                    dead_after[*si].push(node);
                }
            }
        }
        ExecPlan { graph, template, steps, dead_after, arena: RefCell::new(Vec::new()) }
    }

    pub fn graph(&self) -> &Rc<Graph> {
        &self.graph
    }

    /// Execute the plan. Reuses the internal arena when free (the planned
    /// executor never re-enters itself; the fallback covers exotic
    /// aliasing of one plan from two callables).
    pub fn run(&self, inputs: &[Rc<Tensor>]) -> Result<Vec<Tensor>, DepyfError> {
        let g = &*self.graph;
        g.check_inputs(inputs)?;
        let mut borrowed;
        let mut local;
        let env: &mut Vec<Option<Tensor>> = match self.arena.try_borrow_mut() {
            Ok(b) => {
                borrowed = b;
                &mut *borrowed
            }
            Err(_) => {
                local = Vec::new();
                &mut local
            }
        };
        env.clear();
        env.extend(self.template.iter().cloned());
        for (slot, input) in g.inputs.iter().zip(inputs.iter()) {
            env[*slot] = Some((**input).clone());
        }
        for (si, &id) in self.steps.iter().enumerate() {
            let r = eval_op(g, id, env)?;
            env[id] = Some(r);
            for &dead in &self.dead_after[si] {
                env[dead] = None;
            }
        }
        let out = g
            .outputs
            .iter()
            .map(|&o| {
                env[o].clone().ok_or_else(|| DepyfError::Backend(format!("output node {} unevaluated", o)))
            })
            .collect();
        // Drop live tensors now rather than holding them until the next
        // call (the arena itself keeps only empty slots).
        env.clear();
        out
    }
}

/// The eager backend's [`CompiledModule`]: an [`ExecPlan`] built once at
/// lower time, with an optional custom `backend_name` stamp (used by the
/// fallback path and by custom backends that delegate execution here).
pub struct EagerModule {
    plan: ExecPlan,
    backend_name: String,
}

impl EagerModule {
    pub fn new(graph: Rc<Graph>) -> EagerModule {
        EagerModule::with_name(graph, "eager".into())
    }

    pub fn with_name(graph: Rc<Graph>, backend_name: String) -> EagerModule {
        EagerModule { plan: ExecPlan::new(graph), backend_name }
    }

    pub fn from_plan(plan: ExecPlan, backend_name: String) -> EagerModule {
        EagerModule { plan, backend_name }
    }
}

impl CompiledModule for EagerModule {
    fn call(&self, inputs: &[Rc<Tensor>]) -> Result<Vec<Tensor>, DepyfError> {
        self.plan.run(inputs)
    }

    fn backend_name(&self) -> &str {
        &self.backend_name
    }
}

/// Execute with a per-node callback (node id, result) — used by the
/// debugger to step through `__compiled_fn` dumps line by line. Walks
/// nodes directly (no plan): the debugger path trades speed for the
/// callback ordering guarantee.
pub fn execute_traced(
    g: &Graph,
    inputs: &[Rc<Tensor>],
    mut on_node: impl FnMut(usize, &Tensor),
) -> Result<Vec<Tensor>, DepyfError> {
    g.check_inputs(inputs)?;
    let mut env: Vec<Option<Tensor>> = vec![None; g.nodes.len()];
    for (slot, input) in g.inputs.iter().zip(inputs.iter()) {
        env[*slot] = Some((**input).clone());
    }
    for (id, node) in g.nodes.iter().enumerate() {
        match &node.kind {
            NodeKind::Placeholder { .. } => {}
            NodeKind::ConstScalar(v) => env[id] = Some(Tensor::scalar(*v as f32)),
            NodeKind::ConstTensor(t) => env[id] = Some(t.clone()),
            NodeKind::Op(..) => {
                let r = eval_op(g, id, &env)?;
                on_node(id, &r);
                env[id] = Some(r);
            }
        }
    }
    g.outputs
        .iter()
        .map(|&o| env[o].clone().ok_or_else(|| DepyfError::Backend(format!("output node {} unevaluated", o))))
        .collect()
}

/// Plain one-shot execution (tests, oracles). Hot callers should build an
/// [`ExecPlan`] once instead.
pub fn execute(g: &Graph, inputs: &[Rc<Tensor>]) -> Result<Vec<Tensor>, DepyfError> {
    execute_traced(g, inputs, |_, _| {})
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::tensor::Rng;

    #[test]
    fn executes_mlp_block() {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2, 3]);
        let w = g.placeholder("w", &[3, 4]);
        let m = g.add_op(OpKind::MatMul, vec![x, w]).unwrap();
        let r = g.add_op(OpKind::Relu, vec![m]).unwrap();
        let s = g.add_op(OpKind::Sum(None), vec![r]).unwrap();
        g.set_outputs(vec![s]);
        let x_t = Rc::new(Tensor::ones(&[2, 3]));
        let w_t = Rc::new(Tensor::ones(&[3, 4]));
        let out = execute(&g, &[x_t, w_t]).unwrap();
        assert_eq!(out[0].item(), 24.0);
    }

    #[test]
    fn const_nodes() {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2]);
        let c = g.const_scalar(2.0);
        let ct = g.const_tensor(Tensor::new(vec![2], vec![10.0, 20.0]));
        let m = g.add_op(OpKind::Mul, vec![x, c]).unwrap();
        let a = g.add_op(OpKind::Add, vec![m, ct]).unwrap();
        g.set_outputs(vec![a]);
        let out = execute(&g, &[Rc::new(Tensor::new(vec![2], vec![1.0, 2.0]))]).unwrap();
        assert_eq!(out[0].data(), &[12.0, 24.0]);
    }

    #[test]
    fn input_shape_checked() {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2, 3]);
        g.set_outputs(vec![x]);
        assert!(execute(&g, &[Rc::new(Tensor::ones(&[3, 2]))]).is_err());
        assert!(execute(&g, &[]).is_err());
    }

    #[test]
    fn traced_callback_order() {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2]);
        let a = g.add_op(OpKind::Relu, vec![x]).unwrap();
        let b = g.add_op(OpKind::Exp, vec![a]).unwrap();
        g.set_outputs(vec![b]);
        let mut seen = Vec::new();
        execute_traced(&g, &[Rc::new(Tensor::zeros(&[2]))], |id, _| seen.push(id)).unwrap();
        assert_eq!(seen, vec![a, b]);
    }

    fn mlp(n: usize, d: usize) -> Graph {
        let mut g = Graph::new("plan_mlp");
        let x = g.placeholder("x", &[n, d]);
        let w1 = g.placeholder("w1", &[d, d]);
        let w2 = g.placeholder("w2", &[d, d]);
        let c = g.const_scalar(0.5);
        let h = g.add_op(OpKind::MatMul, vec![x, w1]).unwrap();
        let r = g.add_op(OpKind::Relu, vec![h]).unwrap();
        let sc = g.add_op(OpKind::Mul, vec![r, c]).unwrap();
        let o = g.add_op(OpKind::MatMul, vec![sc, w2]).unwrap();
        let sm = g.add_op(OpKind::Softmax, vec![o]).unwrap();
        let s = g.add_op(OpKind::Sum(None), vec![sm]).unwrap();
        g.set_outputs(vec![s]);
        g
    }

    #[test]
    fn plan_matches_unplanned_execution() {
        let g = Rc::new(mlp(4, 8));
        let plan = ExecPlan::new(Rc::clone(&g));
        let mut rng = Rng::new(11);
        for _ in 0..3 {
            let inputs: Vec<Rc<Tensor>> = vec![
                Rc::new(Tensor::randn(&[4, 8], &mut rng)),
                Rc::new(Tensor::randn(&[8, 8], &mut rng)),
                Rc::new(Tensor::randn(&[8, 8], &mut rng)),
            ];
            let via_plan = plan.run(&inputs).unwrap();
            let via_walk = execute(&g, &inputs).unwrap();
            assert_eq!(via_plan.len(), via_walk.len());
            for (a, b) in via_plan.iter().zip(via_walk.iter()) {
                assert!(a.allclose(b, 0.0), "plan diverged from reference");
            }
        }
    }

    #[test]
    fn plan_keeps_intermediate_outputs_alive() {
        // An intermediate that is ALSO an output must survive dead-slot
        // freeing even though later steps consume it.
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[3]);
        let r = g.add_op(OpKind::Relu, vec![x]).unwrap();
        let e = g.add_op(OpKind::Exp, vec![r]).unwrap();
        g.set_outputs(vec![r, e]);
        let plan = ExecPlan::new(Rc::new(g));
        let out = plan.run(&[Rc::new(Tensor::new(vec![3], vec![-1.0, 0.0, 1.0]))]).unwrap();
        assert_eq!(out[0].data(), &[0.0, 0.0, 1.0]);
        assert!((out[1].data()[2] - 1.0f32.exp()).abs() < 1e-6);
    }

    #[test]
    fn plan_checks_inputs_like_reference() {
        let g = Rc::new(mlp(2, 4));
        let plan = ExecPlan::new(Rc::clone(&g));
        assert!(plan.run(&[]).is_err());
        assert!(plan
            .run(&[
                Rc::new(Tensor::ones(&[4, 2])), // transposed: wrong shape
                Rc::new(Tensor::ones(&[4, 4])),
                Rc::new(Tensor::ones(&[4, 4])),
            ])
            .is_err());
    }
}
