//! The dynamic value model of the mini Python (`pylang`) runtime — the
//! analogue of `PyObject`. Everything the VM pushes on its stack is a
//! [`Value`]. Heap values share storage via `Rc`; lists and dicts are
//! interior-mutable like their Python counterparts.

use std::cell::RefCell;
use std::cmp::Ordering;
use std::collections::BTreeMap;
use std::fmt;
use std::rc::Rc;

use crate::bytecode::CodeObject;
use crate::tensor::{Tensor, TensorError};

/// A typed value-model failure: conversions, truthiness, ordering, dict
/// hashing and the VM method tables all report through this enum, so
/// callers can distinguish a type error from a tensor shape error without
/// string matching. `From<ValueError> for String` keeps `?` flowing into
/// the `String`-erroring VM dispatch layers.
#[derive(Clone, Debug, PartialEq)]
pub enum ValueError {
    /// A conversion saw the wrong type (`as_int` & co).
    Type { expected: &'static str, got: &'static str },
    /// Dict key of an unhashable type.
    Unhashable(&'static str),
    /// `bool()` of a multi-element tensor.
    AmbiguousTruth,
    /// `<` between unorderable types.
    Unordered { lhs: &'static str, rhs: &'static str },
    /// NaN made an ordering undefined.
    NanOrder,
    /// A tensor op failed underneath a value-level operation.
    Tensor(TensorError),
    /// Everything else the method tables report (KeyError, arity, missing
    /// attributes/methods, index range...), message-formatted.
    Msg(String),
}

impl fmt::Display for ValueError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ValueError::Type { expected, got } => write!(f, "expected {}, got {}", expected, got),
            ValueError::Unhashable(t) => write!(f, "unhashable dict key: {}", t),
            ValueError::AmbiguousTruth => {
                f.write_str("Boolean value of Tensor with more than one element is ambiguous")
            }
            ValueError::Unordered { lhs, rhs } => {
                write!(f, "'<' not supported between {} and {}", lhs, rhs)
            }
            ValueError::NanOrder => f.write_str("nan comparison"),
            ValueError::Tensor(e) => e.fmt(f),
            ValueError::Msg(m) => f.write_str(m),
        }
    }
}

impl std::error::Error for ValueError {}

impl From<TensorError> for ValueError {
    fn from(e: TensorError) -> ValueError {
        ValueError::Tensor(e)
    }
}

impl From<String> for ValueError {
    fn from(m: String) -> ValueError {
        ValueError::Msg(m)
    }
}

impl From<&str> for ValueError {
    fn from(m: &str) -> ValueError {
        ValueError::Msg(m.to_string())
    }
}

impl From<ValueError> for String {
    fn from(e: ValueError) -> String {
        e.to_string()
    }
}

/// A runtime value.
#[derive(Clone)]
pub enum Value {
    None,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(Rc<str>),
    List(Rc<RefCell<Vec<Value>>>),
    Tuple(Rc<Vec<Value>>),
    Dict(Rc<RefCell<BTreeMap<DictKey, Value>>>),
    Tensor(Rc<Tensor>),
    /// A user function: code object + the name-resolution module + closure cells.
    Func(Rc<Function>),
    /// A native builtin (print, range, len, torch.*, tensor methods...).
    Builtin(Rc<Builtin>),
    /// A bound method: receiver + method name, resolved at call time.
    BoundMethod(Rc<(Value, String)>),
    /// A range object (start, stop, step).
    Range(i64, i64, i64),
    /// A slice object (start, stop, step; `None` = default).
    Slice(Rc<(Value, Value, Value)>),
    /// An iterator (materialized; created by GET_ITER).
    Iter(Rc<RefCell<ValueIter>>),
    /// A compiled-graph callable installed by dynamo (routes to a backend).
    CompiledGraph(Rc<crate::graph::CompiledGraphFn>),
    /// A closure cell.
    Cell(Rc<RefCell<Value>>),
    /// A code object value (what MAKE_FUNCTION consumes).
    Code(Rc<CodeObject>),
}

/// Hashable dict keys (Python-ish subset).
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum DictKey {
    Int(i64),
    Str(String),
    Bool(bool),
}

impl DictKey {
    pub fn from_value(v: &Value) -> Result<DictKey, ValueError> {
        match v {
            Value::Int(i) => Ok(DictKey::Int(*i)),
            Value::Str(s) => Ok(DictKey::Str(s.to_string())),
            Value::Bool(b) => Ok(DictKey::Bool(*b)),
            other => Err(ValueError::Unhashable(other.type_name())),
        }
    }

    pub fn to_value(&self) -> Value {
        match self {
            DictKey::Int(i) => Value::Int(*i),
            DictKey::Str(s) => Value::str(s),
            DictKey::Bool(b) => Value::Bool(*b),
        }
    }
}

/// A materialized iterator.
#[derive(Debug)]
pub struct ValueIter {
    pub items: Vec<Value>,
    pub pos: usize,
}

impl ValueIter {
    pub fn next_item(&mut self) -> Option<Value> {
        let v = self.items.get(self.pos).cloned();
        if v.is_some() {
            self.pos += 1;
        }
        v
    }
}

/// A user-defined function.
pub struct Function {
    pub name: String,
    pub code: Rc<CodeObject>,
    /// Default values for trailing parameters.
    pub defaults: Vec<Value>,
    /// Captured closure cells (indexed by the code object's freevars).
    pub closure: Vec<Rc<RefCell<Value>>>,
}

/// A native builtin function.
pub struct Builtin {
    pub name: String,
    #[allow(clippy::type_complexity)]
    pub func: Box<dyn Fn(&[Value]) -> Result<Value, String>>,
}

impl fmt::Debug for Builtin {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "<builtin {}>", self.name)
    }
}

impl Value {
    pub fn str(s: &str) -> Value {
        Value::Str(Rc::from(s))
    }

    pub fn list(items: Vec<Value>) -> Value {
        Value::List(Rc::new(RefCell::new(items)))
    }

    pub fn tuple(items: Vec<Value>) -> Value {
        Value::Tuple(Rc::new(items))
    }

    pub fn dict() -> Value {
        Value::Dict(Rc::new(RefCell::new(BTreeMap::new())))
    }

    pub fn tensor(t: Tensor) -> Value {
        Value::Tensor(Rc::new(t))
    }

    pub fn builtin(name: &str, f: impl Fn(&[Value]) -> Result<Value, String> + 'static) -> Value {
        Value::Builtin(Rc::new(Builtin { name: name.to_string(), func: Box::new(f) }))
    }

    pub fn type_name(&self) -> &'static str {
        match self {
            Value::None => "NoneType",
            Value::Bool(_) => "bool",
            Value::Int(_) => "int",
            Value::Float(_) => "float",
            Value::Str(_) => "str",
            Value::List(_) => "list",
            Value::Tuple(_) => "tuple",
            Value::Dict(_) => "dict",
            Value::Tensor(_) => "Tensor",
            Value::Func(_) => "function",
            Value::Builtin(_) => "builtin_function_or_method",
            Value::BoundMethod(_) => "method",
            Value::Range(..) => "range",
            Value::Slice(_) => "slice",
            Value::Iter(_) => "iterator",
            Value::CompiledGraph(_) => "compiled_graph",
            Value::Cell(_) => "cell",
            Value::Code(_) => "code",
        }
    }

    /// Python truthiness.
    pub fn truthy(&self) -> Result<bool, ValueError> {
        Ok(match self {
            Value::None => false,
            Value::Bool(b) => *b,
            Value::Int(i) => *i != 0,
            Value::Float(f) => *f != 0.0,
            Value::Str(s) => !s.is_empty(),
            Value::List(l) => !l.borrow().is_empty(),
            Value::Tuple(t) => !t.is_empty(),
            Value::Dict(d) => !d.borrow().is_empty(),
            Value::Range(a, b, s) => {
                if *s > 0 {
                    a < b
                } else {
                    a > b
                }
            }
            Value::Tensor(t) => {
                if t.numel() != 1 {
                    return Err(ValueError::AmbiguousTruth);
                }
                t.item() != 0.0
            }
            _ => true,
        })
    }

    pub fn as_int(&self) -> Result<i64, ValueError> {
        match self {
            Value::Int(i) => Ok(*i),
            Value::Bool(b) => Ok(*b as i64),
            Value::Float(f) => Ok(*f as i64),
            Value::Tensor(t) if t.numel() == 1 => Ok(t.item() as i64),
            other => Err(ValueError::Type { expected: "int", got: other.type_name() }),
        }
    }

    pub fn as_float(&self) -> Result<f64, ValueError> {
        match self {
            Value::Int(i) => Ok(*i as f64),
            Value::Float(f) => Ok(*f),
            Value::Bool(b) => Ok(*b as i64 as f64),
            Value::Tensor(t) if t.numel() == 1 => Ok(t.item() as f64),
            other => Err(ValueError::Type { expected: "float", got: other.type_name() }),
        }
    }

    pub fn as_tensor(&self) -> Result<Rc<Tensor>, ValueError> {
        match self {
            Value::Tensor(t) => Ok(Rc::clone(t)),
            other => Err(ValueError::Type { expected: "Tensor", got: other.type_name() }),
        }
    }

    pub fn is_none(&self) -> bool {
        matches!(self, Value::None)
    }

    /// Structural equality (Python `==` semantics for the supported types).
    pub fn eq_value(&self, other: &Value) -> bool {
        match (self, other) {
            (Value::None, Value::None) => true,
            (Value::Bool(a), Value::Bool(b)) => a == b,
            (Value::Int(a), Value::Int(b)) => a == b,
            (Value::Float(a), Value::Float(b)) => a == b,
            (Value::Int(a), Value::Float(b)) | (Value::Float(b), Value::Int(a)) => *a as f64 == *b,
            (Value::Bool(a), Value::Int(b)) | (Value::Int(b), Value::Bool(a)) => (*a as i64) == *b,
            (Value::Str(a), Value::Str(b)) => a == b,
            (Value::List(a), Value::List(b)) => {
                let (a, b) = (a.borrow(), b.borrow());
                a.len() == b.len() && a.iter().zip(b.iter()).all(|(x, y)| x.eq_value(y))
            }
            (Value::Tuple(a), Value::Tuple(b)) => a.len() == b.len() && a.iter().zip(b.iter()).all(|(x, y)| x.eq_value(y)),
            (Value::Dict(a), Value::Dict(b)) => {
                let (a, b) = (a.borrow(), b.borrow());
                a.len() == b.len() && a.iter().zip(b.iter()).all(|((ka, va), (kb, vb))| ka == kb && va.eq_value(vb))
            }
            (Value::Tensor(a), Value::Tensor(b)) => a.shape() == b.shape() && a.data() == b.data(),
            (Value::Range(a1, b1, c1), Value::Range(a2, b2, c2)) => a1 == a2 && b1 == b2 && c1 == c2,
            _ => false,
        }
    }

    /// Python `<` comparison for orderable types.
    pub fn cmp_value(&self, other: &Value) -> Result<Ordering, ValueError> {
        match (self, other) {
            (Value::Int(a), Value::Int(b)) => Ok(a.cmp(b)),
            (Value::Float(a), Value::Float(b)) => a.partial_cmp(b).ok_or(ValueError::NanOrder),
            (Value::Int(a), Value::Float(b)) => (*a as f64).partial_cmp(b).ok_or(ValueError::NanOrder),
            (Value::Float(a), Value::Int(b)) => a.partial_cmp(&(*b as f64)).ok_or(ValueError::NanOrder),
            (Value::Bool(a), Value::Bool(b)) => Ok(a.cmp(b)),
            (Value::Bool(a), Value::Int(b)) => Ok((*a as i64).cmp(b)),
            (Value::Int(a), Value::Bool(b)) => Ok(a.cmp(&(*b as i64))),
            (Value::Str(a), Value::Str(b)) => Ok(a.cmp(b)),
            (Value::List(a), Value::List(b)) => {
                let (a, b) = (a.borrow(), b.borrow());
                for (x, y) in a.iter().zip(b.iter()) {
                    match x.cmp_value(y)? {
                        Ordering::Equal => continue,
                        o => return Ok(o),
                    }
                }
                Ok(a.len().cmp(&b.len()))
            }
            (Value::Tuple(a), Value::Tuple(b)) => {
                for (x, y) in a.iter().zip(b.iter()) {
                    match x.cmp_value(y)? {
                        Ordering::Equal => continue,
                        o => return Ok(o),
                    }
                }
                Ok(a.len().cmp(&b.len()))
            }
            _ => Err(ValueError::Unordered { lhs: self.type_name(), rhs: other.type_name() }),
        }
    }

    /// Identity (`is`): reference identity for heap types, value identity
    /// for immediates (mirrors small-int caching closely enough for tests).
    pub fn is_identical(&self, other: &Value) -> bool {
        match (self, other) {
            (Value::None, Value::None) => true,
            (Value::Bool(a), Value::Bool(b)) => a == b,
            (Value::Int(a), Value::Int(b)) => a == b,
            (Value::Str(a), Value::Str(b)) => Rc::ptr_eq(a, b),
            (Value::List(a), Value::List(b)) => Rc::ptr_eq(a, b),
            (Value::Tuple(a), Value::Tuple(b)) => Rc::ptr_eq(a, b),
            (Value::Dict(a), Value::Dict(b)) => Rc::ptr_eq(a, b),
            (Value::Tensor(a), Value::Tensor(b)) => Rc::ptr_eq(a, b),
            (Value::Func(a), Value::Func(b)) => Rc::ptr_eq(a, b),
            (Value::Builtin(a), Value::Builtin(b)) => Rc::ptr_eq(a, b),
            _ => false,
        }
    }

    /// Python `repr`.
    pub fn repr(&self) -> String {
        match self {
            Value::None => "None".into(),
            Value::Bool(b) => if *b { "True".into() } else { "False".into() },
            Value::Int(i) => i.to_string(),
            Value::Float(f) => {
                if f.fract() == 0.0 && f.abs() < 1e16 {
                    format!("{:.1}", f)
                } else {
                    format!("{}", f)
                }
            }
            Value::Str(s) => format!("'{}'", s),
            Value::List(l) => {
                let items: Vec<String> = l.borrow().iter().map(|v| v.repr()).collect();
                format!("[{}]", items.join(", "))
            }
            Value::Tuple(t) => {
                let items: Vec<String> = t.iter().map(|v| v.repr()).collect();
                if t.len() == 1 {
                    format!("({},)", items[0])
                } else {
                    format!("({})", items.join(", "))
                }
            }
            Value::Dict(d) => {
                let items: Vec<String> = d.borrow().iter().map(|(k, v)| format!("{}: {}", k.to_value().repr(), v.repr())).collect();
                format!("{{{}}}", items.join(", "))
            }
            Value::Tensor(t) => format!("{}", t),
            Value::Func(f) => format!("<function {}>", f.name),
            Value::Builtin(b) => format!("<builtin {}>", b.name),
            Value::BoundMethod(m) => format!("<bound method {}>", m.1),
            Value::Range(a, b, s) => {
                if *s == 1 {
                    format!("range({}, {})", a, b)
                } else {
                    format!("range({}, {}, {})", a, b, s)
                }
            }
            Value::Slice(s) => format!("slice({}, {}, {})", s.0.repr(), s.1.repr(), s.2.repr()),
            Value::Iter(_) => "<iterator>".into(),
            Value::CompiledGraph(g) => format!("<compiled graph {}>", g.name),
            Value::Cell(_) => "<cell>".into(),
            Value::Code(c) => format!("<code {}>", c.name),
        }
    }

    /// Python `str` (repr except for strings).
    pub fn to_display(&self) -> String {
        match self {
            Value::Str(s) => s.to_string(),
            other => other.repr(),
        }
    }
}

impl fmt::Debug for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.repr())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn truthiness() {
        assert!(!Value::None.truthy().unwrap());
        assert!(Value::Int(3).truthy().unwrap());
        assert!(!Value::Int(0).truthy().unwrap());
        assert!(!Value::str("").truthy().unwrap());
        assert!(Value::str("x").truthy().unwrap());
        assert!(!Value::list(vec![]).truthy().unwrap());
        assert!(Value::tuple(vec![Value::None]).truthy().unwrap());
    }

    #[test]
    fn tensor_truthiness_ambiguous() {
        let t = Value::tensor(Tensor::zeros(&[2]));
        assert!(t.truthy().is_err());
        let s = Value::tensor(Tensor::scalar(1.0));
        assert!(s.truthy().unwrap());
    }

    #[test]
    fn equality_mixed_numeric() {
        assert!(Value::Int(1).eq_value(&Value::Float(1.0)));
        assert!(Value::Bool(true).eq_value(&Value::Int(1)));
        assert!(!Value::Int(1).eq_value(&Value::str("1")));
    }

    #[test]
    fn ordering() {
        assert_eq!(Value::Int(1).cmp_value(&Value::Float(2.0)).unwrap(), Ordering::Less);
        assert_eq!(Value::str("b").cmp_value(&Value::str("a")).unwrap(), Ordering::Greater);
        assert!(Value::Int(1).cmp_value(&Value::str("a")).is_err());
        let a = Value::list(vec![Value::Int(1), Value::Int(2)]);
        let b = Value::list(vec![Value::Int(1), Value::Int(3)]);
        assert_eq!(a.cmp_value(&b).unwrap(), Ordering::Less);
    }

    #[test]
    fn reprs() {
        assert_eq!(Value::Float(2.0).repr(), "2.0");
        assert_eq!(Value::tuple(vec![Value::Int(1)]).repr(), "(1,)");
        assert_eq!(Value::list(vec![Value::str("a")]).repr(), "['a']");
        assert_eq!(Value::Bool(true).repr(), "True");
    }

    #[test]
    fn dict_keys() {
        assert!(DictKey::from_value(&Value::Int(3)).is_ok());
        assert!(DictKey::from_value(&Value::list(vec![])).is_err());
        let k = DictKey::from_value(&Value::str("k")).unwrap();
        assert!(k.to_value().eq_value(&Value::str("k")));
    }

    #[test]
    fn identity_vs_equality() {
        let l1 = Value::list(vec![Value::Int(1)]);
        let l2 = Value::list(vec![Value::Int(1)]);
        assert!(l1.eq_value(&l2));
        assert!(!l1.is_identical(&l2));
        assert!(l1.is_identical(&l1.clone()));
    }
}
