//! Session entry points — re-exports of [`crate::api`]'s fluent builder.
//!
//! The pre-builder constructors (`DebugSession::prepare_debug`,
//! `prepare_debug_with_runtime`, `debug`) were deprecated in the API
//! redesign and are now **removed**; migrate as follows:
//!
//! ```text
//! // old                                         new
//! DebugSession::prepare_debug(dir, kind)    Session::builder().dump_to(dir)
//!                                               .backend_named("eager").build()
//! DebugSession::prepare_debug_with_runtime  Session::builder().dump_to(dir)
//!                                               .backend_named("xla").runtime(rt).build()
//! DebugSession::debug(dir)                  Session::builder().dump_to(dir)
//!                                               .trace(TraceMode::StepGraphs).build()
//! ```
//!
//! `finish()` returns typed [`crate::api::Artifact`]s plus writes a
//! `manifest.json` index.

pub use crate::api::{Session, SessionBuilder, TraceMode};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::ArtifactKind;
    use std::path::PathBuf;

    /// The builder covers the old constructors' workflows end-to-end.
    #[test]
    fn builder_replaces_prepare_debug() {
        let dir: PathBuf = std::env::temp_dir().join(format!("depyf_shimless_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = Session::builder().dump_to(&dir).backend_named("eager").build().unwrap();
        s.run_source("main", "def f(x):\n    return (x * 2).sum()\nprint(f(torch.ones([3])).item())\n")
            .unwrap();
        let artifacts = s.finish().unwrap();
        assert!(artifacts.iter().any(|a| a.kind == ArtifactKind::CompiledGraph));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn builder_replaces_debug_step_tracing() {
        let dir: PathBuf =
            std::env::temp_dir().join(format!("depyf_shimless_dbg_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = Session::builder().dump_to(&dir).trace(TraceMode::StepGraphs).build().unwrap();
        s.debugger.break_at("__compiled_fn_1.py", 2);
        s.run_source("main", "def f(x):\n    return (x * 2).sum()\nprint(f(torch.ones([3])).item())\n")
            .unwrap();
        assert!(s.debugger.events().iter().any(|e| e.file.ends_with("__compiled_fn_1.py")));
        std::fs::remove_dir_all(&dir).ok();
    }
}
