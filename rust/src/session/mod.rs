//! Legacy session entry points — thin deprecated shims over
//! [`crate::api::Session`].
//!
//! The user-facing API now lives in [`crate::api`]: one fluent builder
//! subsumes the three old constructors,
//!
//! ```text
//! // old                                         new
//! DebugSession::prepare_debug(dir, kind)    Session::builder().dump_to(dir)
//!                                               .backend(kind.to_backend()).build()
//! DebugSession::prepare_debug_with_runtime  Session::builder().dump_to(dir)
//!                                               .backend_named("xla").runtime(rt).build()
//! DebugSession::debug(dir)                  Session::builder().dump_to(dir)
//!                                               .trace(TraceMode::StepGraphs).build()
//! ```
//!
//! and `finish()` now returns typed [`crate::api::Artifact`]s plus writes a
//! `manifest.json` index. The shims below keep old call sites compiling
//! (against [`crate::api::DepyfError`] instead of `String` errors) and will
//! be removed in a future release.

use std::path::Path;
use std::rc::Rc;

use crate::api::{DepyfError, XlaBackend};
use crate::backend::BackendKind;
use crate::runtime::Runtime;

pub use crate::api::{Session, SessionBuilder, TraceMode};

/// The pre-builder name for [`Session`].
#[deprecated(note = "renamed to depyf::api::Session (same type)")]
pub type DebugSession = Session;

impl Session {
    /// `with depyf.prepare_debug(dir)` — capture everything into `dir`.
    #[deprecated(note = "use Session::builder().dump_to(dir).backend(kind.to_backend()).build()")]
    pub fn prepare_debug(dir: impl AsRef<Path>, backend: BackendKind) -> Result<Session, DepyfError> {
        Session::builder().dump_to(dir).backend(backend.to_backend()).build()
    }

    /// Same, with a PJRT runtime for the XLA backend.
    #[deprecated(note = "use Session::builder().dump_to(dir).backend_named(\"xla\").runtime(rt).build()")]
    pub fn prepare_debug_with_runtime(
        dir: impl AsRef<Path>,
        runtime: Rc<Runtime>,
    ) -> Result<Session, DepyfError> {
        Session::builder().dump_to(dir).backend(Rc::new(XlaBackend)).runtime(runtime).build()
    }

    /// `with depyf.debug()` — like prepare_debug but graphs run through the
    /// traced eager executor so the debugger can step `__compiled_fn` lines.
    #[deprecated(note = "use Session::builder().dump_to(dir).trace(TraceMode::StepGraphs).build()")]
    pub fn debug(dir: impl AsRef<Path>) -> Result<Session, DepyfError> {
        Session::builder().dump_to(dir).trace(TraceMode::StepGraphs).build()
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::api::ArtifactKind;

    /// The deprecated constructors still work end-to-end.
    #[test]
    fn prepare_debug_shim_still_dumps() {
        let dir = std::env::temp_dir().join(format!("depyf_shim_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = DebugSession::prepare_debug(&dir, BackendKind::Eager).unwrap();
        s.run_source("main", "def f(x):\n    return (x * 2).sum()\nprint(f(torch.ones([3])).item())\n")
            .unwrap();
        let artifacts = s.finish().unwrap();
        assert!(artifacts.iter().any(|a| a.kind == ArtifactKind::CompiledGraph));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn debug_shim_enables_step_tracing() {
        let dir = std::env::temp_dir().join(format!("depyf_shim_dbg_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = DebugSession::debug(&dir).unwrap();
        s.debugger.break_at("__compiled_fn_1.py", 2);
        s.run_source("main", "def f(x):\n    return (x * 2).sum()\nprint(f(torch.ones([3])).item())\n")
            .unwrap();
        assert!(s.debugger.events().iter().any(|e| e.file.ends_with("__compiled_fn_1.py")));
        std::fs::remove_dir_all(&dir).ok();
    }
}
