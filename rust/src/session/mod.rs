//! The user-facing depyf API: [`DebugSession`] is the analogue of the
//! paper's two context managers,
//!
//! ```python
//! with depyf.prepare_debug("dump_dir"):   # capture + dump everything
//!     model(x)
//! with depyf.debug():                      # step through the dumps
//!     model(x)
//! ```
//!
//! `DebugSession::prepare_debug(dir)` wires a VM + dynamo so every hooked
//! call is captured; `finish()` writes the dump files. `enable_debug()`
//! attaches the [`Debugger`] and re-routes compiled graphs through the
//! traced eager executor so `__compiled_fn_*.py` lines can be stepped.

use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

use crate::backend::BackendKind;
use crate::bytecode::IsaVersion;
use crate::debugger::Debugger;
use crate::dynamo::{Dynamo, DynamoConfig, GraphTracer};
use crate::hijack::{dump_all, graph_line_table, link_source, DumpDir};
use crate::runtime::Runtime;
use crate::value::Value;
use crate::vm::{Vm, VmError};

/// Adapter: dynamo per-node graph events → debugger stops at dump lines.
struct GraphDebugAdapter {
    dump_root: PathBuf,
    debugger: Rc<Debugger>,
    /// graph name -> (node id -> line) — filled lazily as graphs compile.
    tables: std::cell::RefCell<HashMap<String, HashMap<usize, u32>>>,
    dynamo: std::cell::RefCell<Option<Rc<Dynamo>>>,
}

impl GraphTracer for GraphDebugAdapter {
    fn on_node(&self, graph_name: &str, node_id: usize, value: &crate::tensor::Tensor) {
        // Resolve (or build) the line table for this graph.
        let line = {
            let mut tables = self.tables.borrow_mut();
            if !tables.contains_key(graph_name) {
                if let Some(d) = self.dynamo.borrow().as_ref() {
                    if let Some((_, g)) = d.graphs().into_iter().find(|(n, _)| n == graph_name) {
                        tables.insert(graph_name.to_string(), graph_line_table(&g));
                    }
                }
            }
            tables.get(graph_name).and_then(|t| t.get(&node_id)).copied()
        };
        if let Some(line) = line {
            let file = self.dump_root.join(format!("{}.py", graph_name));
            self.debugger.graph_stop(&file.to_string_lossy(), line, graph_name, &format!("{}", value));
        }
    }
}

/// A depyf debugging session.
pub struct DebugSession {
    pub vm: Vm,
    pub dynamo: Rc<Dynamo>,
    pub dump: DumpDir,
    pub debugger: Rc<Debugger>,
    adapter: Rc<GraphDebugAdapter>,
    version: IsaVersion,
    source_counter: usize,
}

impl DebugSession {
    /// `with depyf.prepare_debug(dir)` — capture everything into `dir`.
    pub fn prepare_debug(dir: impl AsRef<std::path::Path>, backend: BackendKind) -> Result<DebugSession, String> {
        Self::build(dir, backend, None, false)
    }

    /// Same, with a PJRT runtime for the XLA backend.
    pub fn prepare_debug_with_runtime(
        dir: impl AsRef<std::path::Path>,
        runtime: Rc<Runtime>,
    ) -> Result<DebugSession, String> {
        Self::build(dir, BackendKind::Xla, Some(runtime), false)
    }

    /// `with depyf.debug()` — like prepare_debug but graphs run through the
    /// traced eager executor so the debugger can step `__compiled_fn` lines.
    pub fn debug(dir: impl AsRef<std::path::Path>) -> Result<DebugSession, String> {
        Self::build(dir, BackendKind::Eager, None, true)
    }

    fn build(
        dir: impl AsRef<std::path::Path>,
        backend: BackendKind,
        runtime: Option<Rc<Runtime>>,
        debug_trace: bool,
    ) -> Result<DebugSession, String> {
        let dump = DumpDir::create(dir)?;
        let debugger = Debugger::shared();
        let adapter = Rc::new(GraphDebugAdapter {
            dump_root: dump.root().to_path_buf(),
            debugger: Rc::clone(&debugger),
            tables: Default::default(),
            dynamo: std::cell::RefCell::new(None),
        });
        let config = DynamoConfig {
            backend,
            tracer: if debug_trace { Some(adapter.clone() as Rc<dyn GraphTracer>) } else { None },
            ..Default::default()
        };
        let dynamo = match runtime {
            Some(rt) => Dynamo::with_runtime(config, rt),
            None => Dynamo::new(config),
        };
        *adapter.dynamo.borrow_mut() = Some(Rc::clone(&dynamo));
        let mut vm = Vm::new();
        vm.eval_hook = Some(dynamo.clone());
        vm.tracer = Some(debugger.clone());
        Ok(DebugSession { vm, dynamo, dump, debugger, adapter, version: IsaVersion::V311, source_counter: 0 })
    }

    pub fn set_version(&mut self, v: IsaVersion) {
        self.version = v;
    }

    /// Run a source program inside the session. The source is hijacked into
    /// the dump dir first, so the debugger reports dump-relative locations.
    pub fn run_source(&mut self, name: &str, src: &str) -> Result<Value, VmError> {
        self.source_counter += 1;
        let path = link_source(&self.dump, name, src).map_err(VmError::new)?;
        let code = crate::pylang::compile_module(src, &path.to_string_lossy(), self.version)
            .map_err(|e| VmError::new(e.to_string()))?;
        self.vm.run_module(&code)
    }

    /// Write all dumps (`full_code.py`, `__compiled_fn_*.py`,
    /// `__transformed_*.py`, disassembly) and return the file list.
    pub fn finish(&self) -> Result<Vec<PathBuf>, String> {
        let files = dump_all(&self.dynamo, &self.dump)?;
        let _ = &self.adapter;
        Ok(files)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("depyf_session_{}_{}", tag, std::process::id()))
    }

    #[test]
    fn prepare_debug_dumps_everything() {
        let dir = tmpdir("prep");
        let mut s = DebugSession::prepare_debug(&dir, BackendKind::Eager).unwrap();
        s.run_source(
            "main",
            "def f(x):\n    y = x * 2\n    print('mid')\n    return y.sum()\nprint(f(torch.ones([3])).item())\n",
        )
        .unwrap();
        let files = s.finish().unwrap();
        let names: Vec<String> = files.iter().map(|p| p.file_name().unwrap().to_string_lossy().to_string()).collect();
        assert!(names.iter().any(|n| n == "full_code.py"), "{:?}", names);
        assert!(names.iter().any(|n| n.starts_with("__compiled_fn_")), "{:?}", names);
        assert!(names.iter().any(|n| n.starts_with("__transformed_")), "{:?}", names);
        // The decompiled transform must mention the compiled-fn call.
        let t = names.iter().find(|n| n.starts_with("__transformed___transformed_f") || *n == "__transformed___transformed_f.py");
        let _ = t;
        let content = std::fs::read_to_string(files.iter().find(|p| p.file_name().unwrap().to_string_lossy().starts_with("__transformed_")).unwrap()).unwrap();
        assert!(content.contains("__compiled_fn_"), "{}", content);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn debugger_steps_compiled_graph_lines() {
        let dir = tmpdir("dbg");
        let mut s = DebugSession::debug(&dir).unwrap();
        // Break on line 3 of the first compiled graph (second op node).
        s.debugger.break_at("__compiled_fn_1.py", 3);
        s.run_source("main", "def f(x):\n    return (x * 2 + 1).sum()\nprint(f(torch.ones([4])).item())\n")
            .unwrap();
        let evs = s.debugger.events();
        let graph_stops: Vec<_> = evs.iter().filter(|e| e.file.ends_with("__compiled_fn_1.py")).collect();
        assert_eq!(graph_stops.len(), 1, "{:?}", evs);
        assert_eq!(graph_stops[0].line, 3);
        // The stop carries the intermediate tensor value.
        assert!(graph_stops[0].locals[0].1.contains("tensor"), "{:?}", graph_stops[0].locals);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn source_breakpoints_respect_dump_paths() {
        let dir = tmpdir("src");
        let mut s = DebugSession::prepare_debug(&dir, BackendKind::Eager).unwrap();
        s.debugger.break_at("main.py", 2);
        s.run_source("main", "x = 1\ny = x + 1\nprint(y)\n").unwrap();
        let evs = s.debugger.events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].line, 2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
