//! End-to-end deadline propagation for the serving stack.
//!
//! A [`Deadline`] is an absolute point in time a request must finish by.
//! It is stamped once at the request boundary and then *travels with the
//! work* instead of being re-derived per layer:
//!
//! - the dispatch path publishes it for the duration of a call via
//!   [`with_deadline`] (a thread-local — submission always happens on
//!   the caller's thread);
//! - `AsyncModule::submit` copies [`current_deadline`] into the queued
//!   job, so admission control can shed doomed work and workers can
//!   abort jobs whose budget expired while they sat in the queue;
//! - every `pipelined` stage checks the packet's deadline before
//!   computing, aborting the chain early instead of producing dead
//!   results;
//! - `CachingBackend` refuses to start a cache-miss compile once the
//!   deadline is exhausted.
//!
//! Each such early abort calls [`note_deadline_abort`]; the serve driver
//! reads the process-wide counter as a before/after delta and reports it
//! as `deadline_propagated_aborts`. A monotonic global (rather than a
//! per-layer counter) is what lets queue workers, stage threads and the
//! compile path — which share no state — all account to one number.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// An absolute completion deadline carried by a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Deadline {
    at: Instant,
}

impl Deadline {
    /// A deadline `budget` from now.
    pub fn after(budget: Duration) -> Deadline {
        Deadline { at: Instant::now() + budget }
    }

    /// A deadline `ms` milliseconds from now.
    pub fn in_ms(ms: u64) -> Deadline {
        Deadline::after(Duration::from_millis(ms))
    }

    /// The absolute expiry instant.
    pub fn at(&self) -> Instant {
        self.at
    }

    /// Budget left before expiry (zero once expired).
    pub fn remaining(&self) -> Duration {
        self.at.saturating_duration_since(Instant::now())
    }

    pub fn expired(&self) -> bool {
        Instant::now() >= self.at
    }

    /// The earlier of two deadlines — composing a per-call budget with an
    /// enclosing request budget must never *extend* the request budget.
    pub fn min(self, other: Deadline) -> Deadline {
        if other.at < self.at {
            other
        } else {
            self
        }
    }
}

thread_local! {
    static CURRENT: Cell<Option<Deadline>> = const { Cell::new(None) };
}

/// The deadline of the request currently executing on this thread, if
/// one was published with [`with_deadline`].
pub fn current_deadline() -> Option<Deadline> {
    CURRENT.with(Cell::get)
}

/// Run `f` with `deadline` published as this thread's current deadline
/// (narrowed to the enclosing one if that is tighter), restoring the
/// previous value afterwards — panics included, so a caught panic in a
/// gated region cannot leak a stale deadline into the next request.
pub fn with_deadline<T>(deadline: Deadline, f: impl FnOnce() -> T) -> T {
    struct Restore(Option<Deadline>);
    impl Drop for Restore {
        fn drop(&mut self) {
            CURRENT.with(|c| c.set(self.0));
        }
    }
    let prev = CURRENT.with(Cell::get);
    let effective = prev.map_or(deadline, |outer| deadline.min(outer));
    CURRENT.with(|c| c.set(Some(effective)));
    let _restore = Restore(prev);
    f()
}

/// Process-wide count of deadline-propagated early aborts (monotonic).
static DEADLINE_ABORTS: AtomicU64 = AtomicU64::new(0);

/// Record one early abort: work skipped because its deadline was already
/// exhausted (queued job dropped, stage chain cut, compile refused).
pub fn note_deadline_abort() {
    DEADLINE_ABORTS.fetch_add(1, Ordering::Relaxed);
}

/// Current value of the process-wide abort counter. Readers interested
/// in one run take a before/after delta.
pub fn deadline_abort_count() -> u64 {
    DEADLINE_ABORTS.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn remaining_counts_down_and_expires() {
        let d = Deadline::in_ms(200);
        assert!(!d.expired());
        assert!(d.remaining() <= Duration::from_millis(200));
        let past = Deadline::after(Duration::ZERO);
        assert!(past.expired());
        assert_eq!(past.remaining(), Duration::ZERO);
    }

    #[test]
    fn min_picks_the_tighter_deadline() {
        let tight = Deadline::in_ms(10);
        let loose = Deadline::in_ms(10_000);
        assert_eq!(tight.min(loose), tight);
        assert_eq!(loose.min(tight), tight);
    }

    #[test]
    fn with_deadline_publishes_scoped_and_restores() {
        assert!(current_deadline().is_none());
        let d = Deadline::in_ms(500);
        with_deadline(d, || {
            assert_eq!(current_deadline(), Some(d));
            // Nesting narrows to the tighter of the two.
            let tighter = Deadline::in_ms(1);
            with_deadline(tighter, || {
                assert_eq!(current_deadline(), Some(tighter));
            });
            // A looser inner deadline cannot extend the outer budget.
            let looser = Deadline::in_ms(60_000);
            with_deadline(looser, || {
                assert_eq!(current_deadline(), Some(d));
            });
            assert_eq!(current_deadline(), Some(d));
        });
        assert!(current_deadline().is_none());
    }

    #[test]
    fn with_deadline_restores_after_panic() {
        let d = Deadline::in_ms(500);
        let caught = std::panic::catch_unwind(|| {
            with_deadline(d, || panic!("stage exploded"));
        });
        assert!(caught.is_err());
        assert!(current_deadline().is_none(), "panic must not leak the deadline");
    }

    #[test]
    fn abort_counter_is_monotonic() {
        let before = deadline_abort_count();
        note_deadline_abort();
        note_deadline_abort();
        assert!(deadline_abort_count() >= before + 2);
    }
}
