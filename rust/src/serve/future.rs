//! Future/promise plumbing for asynchronous module calls, plus the small
//! worker pool that backs [`crate::serve::AsyncBackend`].
//!
//! `CallFuture` is deliberately tiny: a one-shot slot guarded by a
//! `Mutex` + `Condvar` pair, not an `std::future::Future` — the serving
//! layer is thread-based, and a blocking `wait()` is what the dispatch
//! path needs. The producing side (`CallPromise`) can be fulfilled at
//! most once; dropping it unfulfilled (a worker panicked, or the pool was
//! torn down with jobs still queued) resolves the future with an error
//! instead of deadlocking the waiter.

use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::api::DepyfError;
use crate::tensor::Tensor;

/// The one-shot result slot shared by a promise/future pair.
enum SlotState {
    Pending,
    Done(Result<Vec<Tensor>, DepyfError>),
}

struct CallSlot {
    state: Mutex<SlotState>,
    ready: Condvar,
}

/// The consumer half of an asynchronous module call: returned by
/// `AsyncModule::submit` (and the pipelined sharded module), resolved by
/// a worker thread.
pub struct CallFuture {
    slot: Arc<CallSlot>,
}

/// The producer half: fulfilled exactly once by the worker that ran the
/// call. Dropping it unfulfilled resolves the future with an error.
pub struct CallPromise {
    slot: Arc<CallSlot>,
    fulfilled: bool,
}

/// Build a connected promise/future pair.
pub(crate) fn call_channel() -> (CallPromise, CallFuture) {
    let slot = Arc::new(CallSlot { state: Mutex::new(SlotState::Pending), ready: Condvar::new() });
    (CallPromise { slot: Arc::clone(&slot), fulfilled: false }, CallFuture { slot })
}

impl CallFuture {
    /// True once the result is in (never blocks).
    pub fn is_ready(&self) -> bool {
        let guard = self.slot.state.lock().unwrap_or_else(PoisonError::into_inner);
        !matches!(*guard, SlotState::Pending)
    }

    /// Block until the worker resolves the call, consuming the future.
    pub fn wait(self) -> Result<Vec<Tensor>, DepyfError> {
        let mut guard = self.slot.state.lock().unwrap_or_else(PoisonError::into_inner);
        loop {
            match std::mem::replace(&mut *guard, SlotState::Pending) {
                SlotState::Done(result) => return result,
                SlotState::Pending => {
                    guard = self.slot.ready.wait(guard).unwrap_or_else(PoisonError::into_inner);
                }
            }
        }
    }

    /// Block for at most `deadline`, consuming the future either way.
    ///
    /// On timeout the call is *abandoned*, not cancelled: the worker keeps
    /// running and its eventual result is discarded when the slot's last
    /// `Arc` drops. The waiter gets `DepyfError::Timeout` and can degrade
    /// or re-dispatch without deadlocking the worker thread.
    pub fn wait_timeout(self, deadline: Duration) -> Result<Vec<Tensor>, DepyfError> {
        let start = Instant::now();
        let mut guard = self.slot.state.lock().unwrap_or_else(PoisonError::into_inner);
        loop {
            match std::mem::replace(&mut *guard, SlotState::Pending) {
                SlotState::Done(result) => return result,
                SlotState::Pending => {
                    // Re-derive the remaining budget each lap so spurious
                    // wakeups can't extend the overall deadline.
                    let remaining = match deadline.checked_sub(start.elapsed()) {
                        Some(r) if r > Duration::ZERO => r,
                        _ => {
                            return Err(DepyfError::Timeout(format!(
                                "async call exceeded its {:?} deadline; call abandoned",
                                deadline
                            )))
                        }
                    };
                    let (g, _timed_out) = self
                        .slot
                        .ready
                        .wait_timeout(guard, remaining)
                        .unwrap_or_else(PoisonError::into_inner);
                    guard = g;
                }
            }
        }
    }
}

impl CallPromise {
    /// Resolve the paired future. Consumes the promise — a promise can be
    /// fulfilled at most once. If a [`CallResolver`] already resolved the
    /// slot (the supervisor abandoned the call), this is a no-op: the
    /// first writer wins and the late result is discarded.
    pub fn fulfill(mut self, result: Result<Vec<Tensor>, DepyfError>) {
        self.fulfilled = true;
        self.resolve(result);
    }

    /// A secondary handle onto the same slot, for a *supervisor* that may
    /// need to resolve the call out from under a wedged worker. First
    /// write wins: whichever of the resolver and the promise resolves
    /// first determines the waiter's result.
    pub(crate) fn resolver(&self) -> CallResolver {
        CallResolver { slot: Arc::clone(&self.slot) }
    }

    fn resolve(&self, result: Result<Vec<Tensor>, DepyfError>) -> bool {
        resolve_slot(&self.slot, result)
    }
}

/// Set the slot if still pending; first write wins.
fn resolve_slot(slot: &CallSlot, result: Result<Vec<Tensor>, DepyfError>) -> bool {
    let mut guard = slot.state.lock().unwrap_or_else(PoisonError::into_inner);
    if !matches!(*guard, SlotState::Pending) {
        return false;
    }
    *guard = SlotState::Done(result);
    slot.ready.notify_all();
    true
}

/// A cloneable out-of-band resolver for a promise's slot (see
/// [`CallPromise::resolver`]). The supervisor's watchdog holds one per
/// in-flight job so it can fail an abandoned call over to the caller —
/// who degrades to eager — while the wedged worker's eventual `fulfill`
/// becomes a no-op.
#[derive(Clone)]
pub(crate) struct CallResolver {
    slot: Arc<CallSlot>,
}

impl CallResolver {
    /// Resolve the call if nobody else has; returns whether this write
    /// won the race.
    pub(crate) fn resolve_if_pending(&self, result: Result<Vec<Tensor>, DepyfError>) -> bool {
        resolve_slot(&self.slot, result)
    }
}

impl Drop for CallPromise {
    fn drop(&mut self) {
        if !self.fulfilled {
            self.resolve(Err(DepyfError::Backend(
                "async call dropped before completion (worker exited or pool shut down)".into(),
            )));
        }
    }
}

/// A job submitted to the pool: a boxed closure run on one worker thread.
pub type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size pool of OS threads draining one shared job queue.
///
/// Workers share a single `mpsc::Receiver` behind a mutex (jobs are
/// coarse — whole module calls — so queue contention is negligible).
/// Dropping the pool closes the queue and joins every worker; queued but
/// unstarted jobs are dropped, which resolves their futures with the
/// `CallPromise` drop error rather than hanging callers.
pub struct WorkerPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `size.max(1)` worker threads.
    pub fn new(size: usize) -> WorkerPool {
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..size.max(1))
            .map(|i| {
                let receiver = Arc::clone(&receiver);
                std::thread::Builder::new()
                    .name(format!("depyf-worker-{}", i))
                    .spawn(move || loop {
                        // Hold the queue lock only for the dequeue, not the job.
                        let job = {
                            let rx = receiver.lock().unwrap_or_else(PoisonError::into_inner);
                            rx.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // queue closed: pool is shutting down
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { sender: Some(sender), workers }
    }

    /// Queue a job. A rejected job is handed *back* along with a typed
    /// error instead of being silently dropped: a shut-down or draining
    /// pool returns [`DepyfError::Runtime`] (transient — the fleet is
    /// restarting, a retry elsewhere can succeed), and the caller decides
    /// whether to run the job inline (codegen's row-tiling recompute
    /// path), resolve its promise with the typed error (async dispatch),
    /// or drop it (the promise's drop error then reports the failure).
    ///
    /// The `worker_pool.submit` fault site fires here: an injected error
    /// rejects the job the same way, so chaos rounds exercise exactly the
    /// rejection path production shutdown takes.
    pub fn submit(&self, job: Job) -> Result<(), (DepyfError, Job)> {
        if let Err(e) = crate::faults::gate(crate::faults::Site::WorkerSubmit) {
            return Err((e, job));
        }
        match &self.sender {
            Some(sender) => sender.send(job).map_err(|mpsc::SendError(job)| {
                (
                    DepyfError::Runtime(
                        "worker pool queue closed mid-shutdown; job rejected".into(),
                    ),
                    job,
                )
            }),
            None => Err((
                DepyfError::Runtime("worker pool is draining/shut down; job rejected".into()),
                job,
            )),
        }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Graceful shutdown that leaves the pool *addressable*: close the
    /// queue, finish queued work, join every worker. Subsequent
    /// [`WorkerPool::submit`] calls get the typed rejection instead of a
    /// silent drop — the drain half of the serve shutdown story.
    pub fn drain(&mut self) {
        self.sender.take();
        for worker in self.workers.drain(..) {
            let _ = worker.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.drain(); // close the queue so workers' recv() errors out, then join
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn future_resolves_across_threads() {
        let (promise, future) = call_channel();
        assert!(!future.is_ready());
        let t = std::thread::spawn(move || {
            promise.fulfill(Ok(vec![Tensor::scalar(7.0)]));
        });
        let out = future.wait().expect("resolved ok");
        assert_eq!(out[0].item(), 7.0);
        t.join().unwrap();
    }

    #[test]
    fn dropped_promise_errors_instead_of_hanging() {
        let (promise, future) = call_channel();
        drop(promise);
        let err = future.wait().expect_err("dropped promise must error");
        assert!(format!("{}", err).contains("dropped before completion"), "{}", err);
    }

    #[test]
    fn pool_runs_jobs_on_worker_threads() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.size(), 3);
        let futures: Vec<CallFuture> = (0..16)
            .map(|i| {
                let (promise, future) = call_channel();
                let queued = pool.submit(Box::new(move || {
                    promise.fulfill(Ok(vec![Tensor::scalar(i as f32 * 2.0)]));
                }));
                assert!(queued.is_ok(), "live pool accepts jobs");
                future
            })
            .collect();
        for (i, f) in futures.into_iter().enumerate() {
            assert_eq!(f.wait().expect("job ok")[0].item(), i as f32 * 2.0);
        }
    }

    #[test]
    fn pool_shutdown_joins_workers() {
        let pool = WorkerPool::new(2);
        let (promise, future) = call_channel();
        assert!(pool.submit(Box::new(move || promise.fulfill(Ok(vec![])))).is_ok());
        assert!(future.wait().is_ok());
        drop(pool); // must not hang
    }

    #[test]
    fn drained_pool_rejects_jobs_with_typed_transient_error() {
        let mut pool = WorkerPool::new(2);
        pool.drain();
        assert_eq!(pool.size(), 0, "drain joins every worker");
        let (promise, future) = call_channel();
        let (err, job) = pool
            .submit(Box::new(move || promise.fulfill(Ok(vec![Tensor::scalar(4.0)]))))
            .err()
            .expect("drained pool must reject");
        assert_eq!(err.layer(), "runtime");
        assert!(err.is_transient(), "rejection is transient: {}", err);
        assert!(format!("{}", err).contains("draining/shut down"), "{}", err);
        // The job comes back intact: the caller can still run it inline
        // (codegen's recompute path) and the waiter gets the real result.
        job();
        assert_eq!(future.wait().expect("inline run fulfills")[0].item(), 4.0);
    }

    #[test]
    fn resolver_beats_late_promise_and_late_fulfill_is_noop() {
        let (promise, future) = call_channel();
        let resolver = promise.resolver();
        assert!(resolver.resolve_if_pending(Err(DepyfError::Runtime("worker stalled".into()))));
        // The waiter sees the supervisor's abandonment...
        let err = future.wait().expect_err("resolver result wins");
        assert_eq!(err.layer(), "runtime");
        // ...and the wedged worker's eventual fulfill is a harmless no-op.
        promise.fulfill(Ok(vec![Tensor::scalar(1.0)]));
        let (promise2, future2) = call_channel();
        let resolver2 = promise2.resolver();
        promise2.fulfill(Ok(vec![Tensor::scalar(2.0)]));
        assert!(!resolver2.resolve_if_pending(Err(DepyfError::Runtime("late".into()))));
        assert_eq!(future2.wait().expect("promise won")[0].item(), 2.0);
    }

    #[test]
    fn zero_size_pool_rounds_up_to_one_worker() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.size(), 1);
    }

    #[test]
    fn wait_timeout_returns_result_when_worker_is_fast() {
        let (promise, future) = call_channel();
        let t = std::thread::spawn(move || {
            promise.fulfill(Ok(vec![Tensor::scalar(3.0)]));
        });
        let out = future.wait_timeout(Duration::from_secs(5)).expect("fast worker beats deadline");
        assert_eq!(out[0].item(), 3.0);
        t.join().unwrap();
    }

    #[test]
    fn wait_timeout_abandons_slow_call_without_blocking_worker() {
        let (promise, future) = call_channel();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(200));
            promise.fulfill(Ok(vec![Tensor::scalar(9.0)])); // must not hang or panic
        });
        let start = Instant::now();
        let err = future
            .wait_timeout(Duration::from_millis(20))
            .expect_err("slow call must time out");
        assert!(start.elapsed() < Duration::from_millis(180), "returned before the worker finished");
        assert_eq!(err.layer(), "timeout");
        assert!(format!("{}", err).contains("deadline"), "{}", err);
        t.join().unwrap(); // worker still completes cleanly after abandonment
    }
}
