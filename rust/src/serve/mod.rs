//! Concurrent serving: compile once, dispatch from many threads.
//!
//! The paper frames dynamo as infrastructure that production workloads
//! hit from many callers at once. This subsystem is that serving story
//! for the reproduction, layered on the thread-safety contract the rest
//! of the crate now provides (process-wide `RwLock` backend registry,
//! `Send + Sync` [`CompiledModule`]s, atomic guard-table usage counters,
//! rename-safe disk cache — see the "Concurrent serving" section of the
//! crate docs):
//!
//! - [`future`]: one-shot call futures and the [`WorkerPool`] behind them.
//! - [`Supervisor`]: the supervised worker fleet behind `async:` — a
//!   bounded admission queue ([`AdmissionPolicy`]: block, shed, or
//!   deadline-aware shed) in front of heartbeat-monitored workers; a
//!   watchdog kills and respawns stalled workers under a restart budget
//!   and re-fulfills abandoned calls with typed errors so callers degrade
//!   to eager instead of hanging.
//! - [`deadline`]: per-request [`Deadline`]s that travel with the work —
//!   published on the dispatching thread ([`with_deadline`]), copied into
//!   queued jobs and pipeline packets, checked before a cache-miss
//!   compile. Every early abort lands in `deadline_propagated_aborts`.
//! - [`AsyncBackend`]: `Capabilities::ASYNC` made real — a wrapper
//!   backend whose modules run calls on the supervised fleet and can
//!   return [`CallFuture`]s (`submit`) instead of blocking (`call`).
//! - [`PipelinedShardedBackend`]: the sharded partition chain with one
//!   stage thread per shard, overlapping shard k of call i with shard
//!   k+1 of call i−1.
//! - [`ModuleCache`] / [`CachingBackend`]: a process-shared compile cache
//!   keyed by graph content hash, so N serving threads compile each
//!   distinct graph once — spilling plan records to the persistent
//!   [`DiskCache`] (`depyf serve` opens it automatically), so a fresh
//!   fleet's first miss consults the plan index before compiling.
//! - [`run_serve`]: the `depyf serve` driver — N OS threads, each running
//!   its own dynamo sessions over the table1 model corpus, outputs
//!   checked against a single-thread reference run, per-thread metrics
//!   merged into one `metrics.json`, throughput and latency percentiles
//!   into `BENCH_serve.json`.

pub mod async_backend;
pub mod deadline;
pub mod future;
pub mod pipeline;
pub mod supervisor;

pub use async_backend::{AsyncBackend, AsyncModule};
pub use deadline::{current_deadline, with_deadline, Deadline};
pub use future::{CallFuture, WorkerPool};
pub use pipeline::{PipelinedShardedBackend, PipelinedShardedModule};
pub use supervisor::{AdmissionPolicy, Supervisor, SupervisorConfig, SupervisorSnapshot};

use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::{Arc, PoisonError, RwLock};
use std::time::Instant;

use crate::api::{
    Backend, Capabilities, CompilePlan, CompileRequest, CompiledModule, DepyfError,
};
use crate::bytecode::IsaVersion;
use crate::corpus::model_cases;
use crate::dynamo::{Dynamo, DynamoConfig};
use crate::graph::OptLevel;
use crate::metrics::MetricsSnapshot;
use crate::runtime::{Counter, DiskCache};
use crate::vm::Vm;

/// A stable small tag for the cache key ([`OptLevel`] carries no data).
fn opt_tag(level: &OptLevel) -> u8 {
    match level {
        OptLevel::O0 => 0,
        OptLevel::O1 => 1,
        OptLevel::O2 => 2,
    }
}

/// A process-shared compile cache: `(backend, opt level, graph content
/// hash)` → compiled module. Reads take the `RwLock` shared, so dispatch
/// threads looking up already-compiled graphs never serialize; compiles
/// happen *outside* the lock and the first finished insert wins.
///
/// With [`ModuleCache::with_disk`], the cache **spills to the persistent
/// plan index**: a memory miss consults the on-disk [`DiskCache`] before
/// compiling (a hit — counted in `disk_hits` and the serve summary —
/// means an earlier fleet already lowered this exact `(backend, opt,
/// graph)` and its compile plan is on record), and a compile whose plan
/// is not yet indexed persists it after lowering. Compiled modules
/// themselves are process-local (they hold live closures), so the disk
/// layer shares *plans* across processes, never executables.
pub struct ModuleCache {
    map: RwLock<HashMap<(String, u8, u64), Arc<dyn CompiledModule>>>,
    hits: Counter,
    misses: Counter,
    disk: Option<Arc<DiskCache>>,
    disk_hits: Counter,
}

impl Default for ModuleCache {
    fn default() -> Self {
        ModuleCache::new()
    }
}

impl ModuleCache {
    pub fn new() -> ModuleCache {
        ModuleCache {
            map: RwLock::new(HashMap::new()),
            hits: Counter::new(),
            misses: Counter::new(),
            disk: None,
            disk_hits: Counter::new(),
        }
    }

    /// A module cache that spills its plan records to `disk` (the same
    /// [`DiskCache`] the PJRT runtime persists HLO into — module records
    /// use a `module:` key prefix, so the namespaces never collide).
    pub fn with_disk(disk: Arc<DiskCache>) -> ModuleCache {
        ModuleCache { disk: Some(disk), ..ModuleCache::new() }
    }

    /// Modules served from cache instead of compiled.
    pub fn hits(&self) -> u64 {
        self.hits.get()
    }

    /// Modules actually compiled through the inner backend.
    pub fn misses(&self) -> u64 {
        self.misses.get()
    }

    /// Memory misses whose plan was already in the persistent index.
    pub fn disk_hits(&self) -> u64 {
        self.disk_hits.get()
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap_or_else(PoisonError::into_inner).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn get(&self, key: &(String, u8, u64)) -> Option<Arc<dyn CompiledModule>> {
        self.map.read().unwrap_or_else(PoisonError::into_inner).get(key).cloned()
    }

    /// Insert unless a racing compile got there first; either way, every
    /// caller ends up holding the same winning module.
    fn insert_if_absent(
        &self,
        key: (String, u8, u64),
        module: Arc<dyn CompiledModule>,
    ) -> Arc<dyn CompiledModule> {
        let mut map = self.map.write().unwrap_or_else(PoisonError::into_inner);
        Arc::clone(map.entry(key).or_insert(module))
    }

    /// Stable persistent-index key for a module cache entry.
    fn disk_key(key: &(String, u8, u64)) -> String {
        format!("module:{}:{}:{:016x}", key.0, key.1, key.2)
    }

    /// Consult the persistent plan index for a memory miss. `true` (and a
    /// `disk_hits` bump) when the plan is already on record — the caller
    /// then skips re-persisting it after compiling.
    fn disk_lookup(&self, key: &str) -> bool {
        let Some(disk) = &self.disk else { return false };
        let hit = disk.get(key).is_some();
        if hit {
            self.disk_hits.bump();
        }
        hit
    }

    /// Persist a freshly-compiled module's plan record. Best-effort, like
    /// every [`DiskCache`] write: IO failure leaves the index cold.
    fn disk_store(&self, key: &str, plan_text: &str, n_outputs: usize) {
        if let Some(disk) = &self.disk {
            disk.put(key, plan_text, n_outputs);
        }
    }
}

/// Wraps an inner backend with a shared [`ModuleCache`]: the serving
/// layer hands one `CachingBackend` (same `Arc`) to every thread's
/// dynamo, so a graph captured by thread 3 reuses the module thread 0
/// compiled.
pub struct CachingBackend {
    inner: Arc<dyn Backend>,
    cache: Arc<ModuleCache>,
}

impl CachingBackend {
    pub fn new(inner: Arc<dyn Backend>, cache: Arc<ModuleCache>) -> CachingBackend {
        CachingBackend { inner, cache }
    }

    pub fn cache(&self) -> &Arc<ModuleCache> {
        &self.cache
    }
}

impl Backend for CachingBackend {
    /// Transparent: sessions report the inner backend's name.
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn capabilities(&self) -> Capabilities {
        self.inner.capabilities() | Capabilities::WRAPPER
    }

    fn plan(&self, req: &CompileRequest) -> Result<CompilePlan, DepyfError> {
        self.inner.plan(req)
    }

    fn lower(&self, req: &CompileRequest, plan: &CompilePlan) -> Result<Arc<dyn CompiledModule>, DepyfError> {
        let key = (self.inner.name().to_string(), opt_tag(&req.opt_level), req.cache_key);
        if let Some(module) = self.cache.get(&key) {
            self.cache.hits.bump();
            return Ok(module);
        }
        // Memory miss: starting a compile is the most expensive thing this
        // path can do — refuse if the requesting call's budget is already
        // spent (the caller degrades to eager; a future request without a
        // deadline will compile and populate the cache).
        if let Some(d) = deadline::current_deadline() {
            if d.expired() {
                deadline::note_deadline_abort();
                return Err(DepyfError::Timeout(format!(
                    "module cache miss for '{}': request deadline exhausted; compile aborted before lowering",
                    req.name
                )));
            }
        }
        // Consult the persistent plan index before compiling.
        let disk_key = ModuleCache::disk_key(&key);
        let plan_on_record = self.cache.disk_lookup(&disk_key);
        // Compile outside the lock: a slow lower on one thread must not
        // block other threads' cache reads.
        let module = self.inner.lower(req, plan)?;
        self.cache.misses.bump();
        // First-insert-wins is unchanged: the winning module comes from the
        // in-memory entry, never from disk.
        let module = self.cache.insert_if_absent(key, module);
        if !plan_on_record {
            self.cache.disk_store(&disk_key, &plan.to_json(), req.graph.outputs.len());
        }
        Ok(module)
    }
}

/// Options for [`run_serve`] (mirrors `depyf serve` flags).
pub struct ServeOptions {
    /// Concurrent serving threads (the CLI allows 1..=256).
    pub threads: usize,
    /// Passes over the model corpus per thread.
    pub iters: usize,
    /// Backend name; supports the `recording:<inner>`, `async:<inner>`,
    /// and `resilient:<inner>` wrapper prefixes. Runtime-requiring
    /// backends (xla) are rejected: the PJRT client is thread-confined.
    pub backend: String,
    /// Where `metrics.json` and `BENCH_serve.json` land.
    pub out_dir: PathBuf,
    /// Per-call deadline (`--deadline-ms`): calls exceeding it are
    /// abandoned and served by the eager fallback.
    pub deadline_ms: Option<u64>,
    /// Admission policy for the `async:` supervisor queue (`--admission`).
    pub admission: AdmissionPolicy,
    /// Supervisor queue bound (`--queue-cap`).
    pub queue_cap: usize,
    /// Supervised workers behind an `async:` backend (`--pool-workers`).
    pub pool_workers: usize,
    /// Heartbeat stall budget before the watchdog kills a worker
    /// (`--stall-ms`).
    pub stall_ms: u64,
}

/// Knobs for one in-memory serve run beyond thread count and corpus
/// size: the per-call deadline, the plan-spill disk, and the supervision
/// tuning applied when the backend resolves to an `async:` wrapper.
#[derive(Clone)]
pub struct ServeTuning {
    pub deadline_ms: Option<u64>,
    pub disk: Option<Arc<DiskCache>>,
    pub admission: AdmissionPolicy,
    pub queue_cap: usize,
    pub workers: usize,
    pub stall_ms: u64,
    /// Supervisor restart budget. Not CLI-exposed; chaos rounds raise it
    /// so long fault sequences keep the exact kill/respawn reconciliation
    /// instead of tripping the give-up path.
    pub max_restarts: u32,
}

impl Default for ServeTuning {
    fn default() -> ServeTuning {
        let cfg = SupervisorConfig::default();
        ServeTuning {
            deadline_ms: None,
            disk: None,
            admission: cfg.policy,
            queue_cap: cfg.queue_cap,
            workers: cfg.workers,
            stall_ms: cfg.stall_ms,
            max_restarts: cfg.max_restarts,
        }
    }
}

impl ServeTuning {
    /// The supervision config an `async:` backend resolved under this
    /// tuning gets (the backoff base stays at its default: nothing needs
    /// to tune it).
    fn supervisor_config(&self) -> SupervisorConfig {
        SupervisorConfig {
            workers: self.workers,
            queue_cap: self.queue_cap,
            policy: self.admission,
            stall_ms: self.stall_ms,
            max_restarts: self.max_restarts,
            ..SupervisorConfig::default()
        }
    }
}

/// What one serving thread did.
struct ThreadReport {
    case_runs: u64,
    errors: u64,
    failures: Vec<String>,
    latencies_ms: Vec<f64>,
    metrics: MetricsSnapshot,
    /// True for the synthesized report of a thread that panicked clean
    /// through `run_worker` (never for a thread that finished).
    died: bool,
}

/// Aggregated result of one serve run (plus, from [`run_serve`], the
/// single-thread baseline it was measured against).
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub backend: String,
    pub threads: usize,
    pub iters: usize,
    /// Total dynamo sessions driven (threads × corpus cases × iters).
    pub case_runs: u64,
    /// Case runs that errored or diverged from the single-thread
    /// reference output.
    pub errors: u64,
    /// First few divergence descriptions, for the report.
    pub failures: Vec<String>,
    pub elapsed_ms: f64,
    /// Case runs per second, wall clock.
    pub throughput: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub module_cache_hits: u64,
    pub module_cache_misses: u64,
    /// Memory misses whose compile plan was already in the persistent
    /// on-disk index (0 when serving without a disk cache).
    pub module_cache_disk_hits: u64,
    /// Serving threads that panicked clean through `run_worker` (anything
    /// here makes [`run_serve`] exit non-zero).
    pub dead_threads: u64,
    /// Merged across every thread's sessions.
    pub metrics: MetricsSnapshot,
    /// Filled by [`run_serve`]: the 1-thread reference throughput and the
    /// resulting scaling factor.
    pub baseline_throughput: Option<f64>,
    pub speedup: Option<f64>,
}

impl ServeReport {
    /// Human-readable summary printed by `depyf serve`.
    pub fn render(&self) -> String {
        let mut out = format!(
            "depyf serve: backend={} threads={} iters={}\n  case-runs={} errors={} elapsed={:.1}ms throughput={:.1} runs/s\n  latency p50={:.3}ms p99={:.3}ms\n  module-cache hits={} misses={} disk_hits={}\n  dynamo: captures={} cache_hits={} cache_misses={} graph_breaks={} fallbacks={} evictions={}\n",
            self.backend,
            self.threads,
            self.iters,
            self.case_runs,
            self.errors,
            self.elapsed_ms,
            self.throughput,
            self.p50_ms,
            self.p99_ms,
            self.module_cache_hits,
            self.module_cache_misses,
            self.module_cache_disk_hits,
            self.metrics.captures,
            self.metrics.cache_hits,
            self.metrics.cache_misses,
            self.metrics.graph_breaks,
            self.metrics.fallbacks,
            self.metrics.evictions,
        );
        out.push_str(&format!(
            "  resilience: retries={} degraded_calls={} degraded_compiles={} breaker_trips={} breaker_skips={} timeouts={} panics_caught={} dead_threads={}\n",
            self.metrics.retries,
            self.metrics.degraded_calls,
            self.metrics.degraded_compiles,
            self.metrics.breaker_trips,
            self.metrics.breaker_skips,
            self.metrics.timeouts,
            self.metrics.panics_caught,
            self.dead_threads,
        ));
        out.push_str(&format!(
            "  supervision: sheds={} respawns={} watchdog_kills={} deadline_aborts={} queue_depth_p99={}\n",
            self.metrics.sheds,
            self.metrics.respawns,
            self.metrics.watchdog_kills,
            self.metrics.deadline_propagated_aborts,
            self.metrics.queue_depth_p99,
        ));
        if let (Some(base), Some(speedup)) = (self.baseline_throughput, self.speedup) {
            out.push_str(&format!(
                "  baseline(1 thread)={:.1} runs/s speedup={:.2}x\n",
                base, speedup
            ));
        }
        for f in &self.failures {
            out.push_str(&format!("  FAIL {}\n", f));
        }
        out
    }

    /// The `"serve"` object inlined into the merged `metrics.json`.
    fn to_serve_json(&self) -> String {
        format!(
            "{{\"backend\": \"{}\", \"threads\": {}, \"iters\": {}, \"case_runs\": {}, \"errors\": {}, \"dead_threads\": {}, \"throughput_runs_per_s\": {:.3}, \"p50_ms\": {:.3}, \"p99_ms\": {:.3}, \"module_cache_hits\": {}, \"module_cache_misses\": {}, \"module_cache_disk_hits\": {}, \"sheds\": {}, \"respawns\": {}, \"watchdog_kills\": {}, \"deadline_propagated_aborts\": {}, \"queue_depth_p99\": {}}}",
            crate::api::json::escape(&self.backend),
            self.threads,
            self.iters,
            self.case_runs,
            self.errors,
            self.dead_threads,
            self.throughput,
            self.p50_ms,
            self.p99_ms,
            self.module_cache_hits,
            self.module_cache_misses,
            self.module_cache_disk_hits,
            self.metrics.sheds,
            self.metrics.respawns,
            self.metrics.watchdog_kills,
            self.metrics.deadline_propagated_aborts,
            self.metrics.queue_depth_p99,
        )
    }
}

/// Resolve a serve backend name, honoring the CLI's wrapper prefixes.
/// An `async:` backend gets the tuning's supervision config, and its
/// [`Supervisor`] handle is returned alongside so the driver can drain
/// the fleet and fold its counters into the merged report.
fn resolve_serve_backend(
    name: &str,
    tuning: &ServeTuning,
) -> Result<(Arc<dyn Backend>, Option<Arc<Supervisor>>), DepyfError> {
    if let Some(inner) = name.strip_prefix("recording:") {
        return crate::backend::recording::RecordingBackend::wrapping(inner)
            .map(|b| (Arc::new(b) as Arc<dyn Backend>, None));
    }
    if let Some(inner) = name.strip_prefix("async:") {
        let backend = AsyncBackend::wrapping_with(inner, tuning.supervisor_config())?;
        let sup = backend.supervisor();
        return Ok((Arc::new(backend) as Arc<dyn Backend>, Some(sup)));
    }
    crate::api::lookup_backend(name)
        .map(|b| (b, None))
        .ok_or_else(|| {
            DepyfError::Backend(format!(
                "serve: unknown backend '{}' (registered: {})",
                name,
                crate::api::backend_names().join(", ")
            ))
        })
}

/// One unit of serving work: a corpus program plus the reference output a
/// plain (uncompiled, single-thread) interpreter produced for it.
struct WorkItem {
    name: String,
    source: String,
    expected: String,
}

/// Build the corpus: every table1 model case (capped at `limit`), with
/// its single-thread reference output.
fn build_corpus(limit: usize) -> Result<Vec<WorkItem>, DepyfError> {
    let mut items = Vec::new();
    for case in model_cases().into_iter().take(limit) {
        let vm = Vm::new();
        vm.exec_source(&case.source, IsaVersion::V310).map_err(DepyfError::Vm)?;
        items.push(WorkItem { name: case.name, source: case.source, expected: vm.take_output() });
    }
    Ok(items)
}

/// Nearest-rank percentile over an already-sorted slice.
fn percentile(sorted_ms: &[f64], p: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_ms.len() as f64 - 1.0) * p).round() as usize;
    sorted_ms[idx.min(sorted_ms.len() - 1)]
}

/// Run one serving thread: `iters` passes over the corpus, a fresh dynamo
/// session per case run (the cross-run sharing is the module cache inside
/// `backend`), output checked against the reference. With a deadline
/// configured, each case run executes under [`with_deadline`], so the
/// request budget reaches queue admission, pipeline packets and the
/// compile path via the thread-local — not just the per-call watchdog.
fn run_worker(
    backend: Arc<dyn Backend>,
    corpus: Arc<Vec<WorkItem>>,
    iters: usize,
    deadline_ms: Option<u64>,
) -> ThreadReport {
    let mut report = ThreadReport {
        case_runs: 0,
        errors: 0,
        failures: Vec::new(),
        latencies_ms: Vec::new(),
        metrics: MetricsSnapshot::default(),
        died: false,
    };
    for _ in 0..iters {
        for item in corpus.iter() {
            let t0 = Instant::now();
            let dynamo = Dynamo::new(DynamoConfig {
                backend: Arc::clone(&backend),
                deadline_ms,
                ..DynamoConfig::default()
            });
            let mut vm = Vm::new();
            vm.eval_hook = Some(dynamo.clone());
            let run = || vm.exec_source(&item.source, IsaVersion::V310);
            let outcome = match deadline_ms {
                // The whole case run shares one request budget; per-call
                // dispatch narrows to the tighter of the two.
                Some(ms) => with_deadline(Deadline::in_ms(ms), run),
                None => run(),
            };
            report.latencies_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            report.case_runs += 1;
            // metrics_snapshot (not metrics.snapshot): folds the session's
            // call-level retry/degrade/timeout counters into the snapshot.
            report.metrics.merge(&dynamo.metrics_snapshot());
            match outcome {
                Err(e) => {
                    report.errors += 1;
                    if report.failures.len() < 4 {
                        report.failures.push(format!("{}: vm error: {}", item.name, e));
                    }
                }
                Ok(_) => {
                    let got = vm.take_output();
                    if got != item.expected {
                        report.errors += 1;
                        if report.failures.len() < 4 {
                            report.failures.push(format!(
                                "{}: output diverged from single-thread reference",
                                item.name
                            ));
                        }
                    }
                }
            }
        }
    }
    report
}

/// Drive `threads` concurrent serving threads over the first `limit`
/// table1 model cases, `iters` passes each, through one shared module
/// cache. Pure in-memory — [`run_serve`] adds the report files.
pub fn serve_once(
    threads: usize,
    iters: usize,
    backend_name: &str,
    limit: usize,
) -> Result<ServeReport, DepyfError> {
    serve_once_tuned(threads, iters, backend_name, limit, ServeTuning::default())
}

/// [`serve_once`] with a per-call deadline. Every serve run wraps the
/// inner backend in a [`ResilientBackend`] (under the module cache, so
/// cache hits never touch the breaker); an explicit `resilient:` prefix
/// is stripped first so the wrap happens exactly once.
pub fn serve_once_with(
    threads: usize,
    iters: usize,
    backend_name: &str,
    limit: usize,
    deadline_ms: Option<u64>,
) -> Result<ServeReport, DepyfError> {
    serve_once_tuned(
        threads,
        iters,
        backend_name,
        limit,
        ServeTuning { deadline_ms, ..ServeTuning::default() },
    )
}

/// [`serve_once_with`] plus an optional persistent [`DiskCache`] the
/// module cache spills plan records into (see [`ModuleCache::with_disk`]).
pub fn serve_once_spilling(
    threads: usize,
    iters: usize,
    backend_name: &str,
    limit: usize,
    deadline_ms: Option<u64>,
    disk: Option<Arc<DiskCache>>,
) -> Result<ServeReport, DepyfError> {
    serve_once_tuned(
        threads,
        iters,
        backend_name,
        limit,
        ServeTuning { deadline_ms, disk, ..ServeTuning::default() },
    )
}

/// The full-knob serve run (what `depyf serve` uses): deadline, plan
/// spill, and supervision tuning for `async:` backends. After the
/// serving threads join, an `async:` backend's supervisor is drained
/// (stop admitting, finish in-flight) and its shed/respawn/kill/depth
/// counters fold into the merged report, alongside the run's delta of
/// the process-wide deadline-propagated-abort counter.
pub fn serve_once_tuned(
    threads: usize,
    iters: usize,
    backend_name: &str,
    limit: usize,
    tuning: ServeTuning,
) -> Result<ServeReport, DepyfError> {
    let deadline_ms = tuning.deadline_ms;
    let inner_name = match backend_name {
        "resilient" => "eager",
        other => other.strip_prefix("resilient:").unwrap_or(other),
    };
    let (inner, supervisor) = resolve_serve_backend(inner_name, &tuning)?;
    if inner.requires_runtime() {
        return Err(DepyfError::Backend(format!(
            "serve: backend '{}' requires the PJRT runtime, which is thread-confined",
            backend_name
        )));
    }
    let resilient = Arc::new(crate::backend::ResilientBackend::new(inner));
    let rstats = resilient.stats();
    let cache = Arc::new(match tuning.disk {
        Some(d) => ModuleCache::with_disk(d),
        None => ModuleCache::new(),
    });
    let backend: Arc<dyn Backend> =
        Arc::new(CachingBackend::new(resilient as Arc<dyn Backend>, Arc::clone(&cache)));
    let corpus = Arc::new(build_corpus(limit)?);
    if corpus.is_empty() {
        return Err(DepyfError::Backend("serve: empty corpus".into()));
    }

    let aborts_before = deadline::deadline_abort_count();
    let t0 = Instant::now();
    let reports: Vec<ThreadReport> = if threads <= 1 {
        vec![run_worker(backend, corpus, iters, deadline_ms)]
    } else {
        let handles: Vec<_> = (0..threads)
            .map(|i| {
                let backend = Arc::clone(&backend);
                let corpus = Arc::clone(&corpus);
                std::thread::Builder::new()
                    .name(format!("depyf-serve-{}", i))
                    .spawn(move || run_worker(backend, corpus, iters, deadline_ms))
                    .expect("spawn serve thread")
            })
            .collect();
        // A panicked worker becomes a synthesized failure report instead
        // of killing the whole serve run: the other threads' results (and
        // the fact that one thread died) still reach the exit summary.
        handles
            .into_iter()
            .enumerate()
            .map(|(i, h)| match h.join() {
                Ok(report) => report,
                Err(payload) => {
                    let e = DepyfError::from_panic(&format!("serve thread {}", i), payload);
                    ThreadReport {
                        case_runs: 0,
                        errors: 1,
                        failures: vec![format!("{}", e)],
                        latencies_ms: Vec::new(),
                        metrics: MetricsSnapshot::default(),
                        died: true,
                    }
                }
            })
            .collect()
    };
    let elapsed_ms = t0.elapsed().as_secs_f64() * 1e3;

    let mut merged = MetricsSnapshot::default();
    let mut latencies = Vec::new();
    let mut case_runs = 0u64;
    let mut errors = 0u64;
    let mut dead_threads = 0u64;
    let mut failures = Vec::new();
    for r in reports {
        merged.merge(&r.metrics);
        latencies.extend(r.latencies_ms);
        case_runs += r.case_runs;
        errors += r.errors;
        dead_threads += r.died as u64;
        failures.extend(r.failures);
    }
    // Compile-level resilience lives in the shared backend wrapper, not in
    // any one thread's session metrics: fold it in once, here.
    merged.retries += rstats.retries();
    merged.breaker_trips += rstats.trips();
    merged.breaker_skips += rstats.skips();
    merged.panics_caught += rstats.panics();
    // Supervision counters live in the shared fleet, likewise folded once.
    // Drain first: stop admitting, let in-flight jobs finish, join the
    // workers — the snapshot is then deterministic for this run.
    if let Some(sup) = supervisor {
        sup.drain();
        sup.snapshot().fold_into(&mut merged);
    }
    // Deadline-propagated aborts are a process-global (queue workers,
    // stage threads and the compile path share no state); this run's
    // share is the before/after delta.
    merged.deadline_propagated_aborts +=
        deadline::deadline_abort_count().saturating_sub(aborts_before);
    failures.truncate(8);
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    Ok(ServeReport {
        backend: backend_name.to_string(),
        threads,
        iters,
        case_runs,
        errors,
        failures,
        elapsed_ms,
        throughput: if elapsed_ms > 0.0 { case_runs as f64 / (elapsed_ms / 1e3) } else { 0.0 },
        p50_ms: percentile(&latencies, 0.50),
        p99_ms: percentile(&latencies, 0.99),
        module_cache_hits: cache.hits(),
        module_cache_misses: cache.misses(),
        module_cache_disk_hits: cache.disk_hits(),
        dead_threads,
        metrics: merged,
        baseline_throughput: None,
        speedup: None,
    })
}

/// The `depyf serve` entry point: measure a 1-thread baseline, then the
/// requested thread count, write `metrics.json` (merged per-thread dynamo
/// counters + a `"serve"` summary object) and `BENCH_serve.json`
/// (throughput vs thread count) into `opts.out_dir`, and fail hard if any
/// case run diverged from the single-thread reference.
pub fn run_serve(opts: &ServeOptions) -> Result<ServeReport, DepyfError> {
    // The fleet-level plan index: same directory the PJRT runtime uses
    // (`$DEPYF_CACHE_DIR`, default `.depyf_cache`). A broken cache dir
    // must not take down serving — spill is simply disabled.
    let cache_dir = std::env::var(crate::runtime::CACHE_DIR_ENV)
        .unwrap_or_else(|_| ".depyf_cache".into());
    let disk = DiskCache::open(&cache_dir).ok().map(Arc::new);
    let tuning = ServeTuning {
        deadline_ms: opts.deadline_ms,
        disk,
        admission: opts.admission,
        queue_cap: opts.queue_cap,
        workers: opts.pool_workers,
        stall_ms: opts.stall_ms,
        ..ServeTuning::default()
    };
    let baseline =
        serve_once_tuned(1, opts.iters, &opts.backend, usize::MAX, tuning.clone())?;
    let mut report = if opts.threads == 1 {
        baseline.clone()
    } else {
        serve_once_tuned(opts.threads, opts.iters, &opts.backend, usize::MAX, tuning)?
    };
    report.baseline_throughput = Some(baseline.throughput);
    report.speedup = Some(if baseline.throughput > 0.0 {
        report.throughput / baseline.throughput
    } else {
        0.0
    });

    std::fs::create_dir_all(&opts.out_dir)
        .map_err(|e| DepyfError::io(opts.out_dir.display(), e))?;
    let metrics_path = opts.out_dir.join("metrics.json");
    let metrics_json = report.metrics.to_json_with(Some(("serve", &report.to_serve_json())));
    std::fs::write(&metrics_path, metrics_json)
        .map_err(|e| DepyfError::io(metrics_path.display(), e))?;

    let bench_path = opts.out_dir.join("BENCH_serve.json");
    let speedup = report.speedup.unwrap_or(0.0);
    let entries: Vec<(String, f64, &str)> = vec![
        ("throughput_t1".to_string(), baseline.throughput, "runs/s"),
        (format!("throughput_t{}", report.threads), report.throughput, "runs/s"),
        (format!("speedup_1_to_{}", report.threads), speedup, "x"),
        (format!("p50_t{}", report.threads), report.p50_ms, "ms"),
        (format!("p99_t{}", report.threads), report.p99_ms, "ms"),
        ("retries".to_string(), report.metrics.retries as f64, "count"),
        (
            "degraded".to_string(),
            (report.metrics.degraded_calls + report.metrics.degraded_compiles) as f64,
            "count",
        ),
        ("breaker_trips".to_string(), report.metrics.breaker_trips as f64, "count"),
        ("timeouts".to_string(), report.metrics.timeouts as f64, "count"),
        ("panics_caught".to_string(), report.metrics.panics_caught as f64, "count"),
        ("dead_threads".to_string(), report.dead_threads as f64, "count"),
        ("sheds".to_string(), report.metrics.sheds as f64, "count"),
        ("respawns".to_string(), report.metrics.respawns as f64, "count"),
        ("watchdog_kills".to_string(), report.metrics.watchdog_kills as f64, "count"),
        (
            "deadline_propagated_aborts".to_string(),
            report.metrics.deadline_propagated_aborts as f64,
            "count",
        ),
        ("queue_depth_p99".to_string(), report.metrics.queue_depth_p99 as f64, "count"),
    ];
    let body: Vec<String> = entries
        .iter()
        .map(|(name, value, unit)| {
            format!(
                "    {{\"bench\": \"serve\", \"name\": \"{}\", \"value\": {:.3}, \"unit\": \"{}\"}}",
                name, value, unit
            )
        })
        .collect();
    let bench_json = format!(
        "{{\n  \"schema_version\": 1,\n  \"entries\": [\n{}\n  ]\n}}\n",
        body.join(",\n")
    );
    std::fs::write(&bench_path, bench_json)
        .map_err(|e| DepyfError::io(bench_path.display(), e))?;

    if report.dead_threads > 0 {
        return Err(DepyfError::Backend(format!(
            "serve: {} of {} serving threads died ({})",
            report.dead_threads,
            report.threads,
            report.failures.join(" | ")
        )));
    }
    if report.errors > 0 {
        return Err(DepyfError::Backend(format!(
            "serve: {} of {} case runs failed or diverged from the single-thread reference ({})",
            report.errors,
            report.case_runs,
            report.failures.join(" | ")
        )));
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::EagerBackend;
    use crate::graph::{Graph, OpKind};
    use crate::tensor::Tensor;

    fn mul_graph() -> Graph {
        let mut g = Graph::new("g");
        let a = g.placeholder("a", &[2]);
        let b = g.placeholder("b", &[2]);
        let m = g.add_op(OpKind::Mul, vec![a, b]).unwrap();
        g.set_outputs(vec![m]);
        g
    }

    #[test]
    fn module_cache_shares_compiles_across_threads() {
        let cache = Arc::new(ModuleCache::new());
        let backend: Arc<dyn Backend> =
            Arc::new(CachingBackend::new(Arc::new(EagerBackend), Arc::clone(&cache)));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let backend = Arc::clone(&backend);
                std::thread::spawn(move || {
                    let req = CompileRequest::new("__compiled_fn_1", Arc::new(mul_graph()));
                    let plan = backend.plan(&req).expect("plan");
                    let module = backend.lower(&req, &plan).expect("lower");
                    let a = Rc::new(Tensor::new(vec![2], vec![2.0, 3.0]));
                    let b = Rc::new(Tensor::new(vec![2], vec![4.0, 5.0]));
                    module.call(&[a, b]).expect("call")[0].data().to_vec()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().expect("thread"), vec![8.0, 15.0]);
        }
        // Same content hash everywhere: exactly one module in the cache,
        // and every lowering after the first was a hit.
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.hits() + cache.misses(), 4);
        assert!(cache.hits() >= 1, "hits={} misses={}", cache.hits(), cache.misses());
    }

    #[test]
    fn module_cache_spills_plans_to_disk_and_counts_disk_hits() {
        let dir = std::env::temp_dir().join(format!("depyf_spill_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let disk = Arc::new(DiskCache::open(&dir).unwrap());
        // Fleet 1: memory miss + index miss → compile, persist the plan.
        let c1 = Arc::new(ModuleCache::with_disk(Arc::clone(&disk)));
        let b1 = CachingBackend::new(Arc::new(EagerBackend), Arc::clone(&c1));
        let req = CompileRequest::new("__compiled_fn_1", Arc::new(mul_graph()));
        let plan = b1.plan(&req).unwrap();
        b1.lower(&req, &plan).unwrap();
        assert_eq!((c1.misses(), c1.disk_hits()), (1, 0));
        assert_eq!(disk.len(), 1, "plan record must be persisted");
        // Fleet 2 (a fresh process, simulated by a fresh ModuleCache):
        // memory miss, but the plan index already has the record.
        let c2 = Arc::new(ModuleCache::with_disk(Arc::clone(&disk)));
        let b2 = CachingBackend::new(Arc::new(EagerBackend), Arc::clone(&c2));
        let module = b2.lower(&req, &plan).unwrap();
        assert_eq!((c2.misses(), c2.disk_hits()), (1, 1));
        // First-insert-wins untouched: the next lower is a pure memory hit
        // on the same winning module, and nothing is rewritten on disk.
        let again = b2.lower(&req, &plan).unwrap();
        assert!(Arc::ptr_eq(&module, &again));
        assert_eq!(c2.hits(), 1);
        assert_eq!(disk.len(), 1);
        // The persisted record is the compile plan itself, parseable back.
        let key = ModuleCache::disk_key(&("eager".into(), opt_tag(&req.opt_level), req.cache_key));
        let (text, n) = disk.get(&key).expect("indexed plan record");
        assert_eq!(n, 1);
        assert!(CompilePlan::parse(&text).is_ok(), "persisted text must be a valid plan");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn serve_once_multithreaded_matches_reference() {
        let report = serve_once(3, 1, "eager", 4).expect("serve");
        assert_eq!(report.errors, 0, "failures: {:?}", report.failures);
        assert_eq!(report.case_runs, 3 * 4);
        assert!(report.metrics.captures > 0, "dynamo never captured anything");
        assert!(report.p99_ms >= report.p50_ms);
        assert!(report.module_cache_hits + report.module_cache_misses > 0);
    }

    #[test]
    fn serve_once_rejects_runtime_backends_and_unknown_names() {
        let err = serve_once(1, 1, "xla", 1).expect_err("xla must be rejected");
        assert!(format!("{}", err).contains("thread-confined"), "{}", err);
        let err = serve_once(1, 1, "no-such-backend", 1).expect_err("unknown name");
        assert!(format!("{}", err).contains("unknown backend"), "{}", err);
    }

    #[test]
    fn serve_report_render_and_json() {
        let report = serve_once(2, 1, "async:eager", 3).expect("serve");
        assert_eq!(report.errors, 0, "failures: {:?}", report.failures);
        let text = report.render();
        assert!(text.contains("backend=async:eager"), "{}", text);
        let json = crate::api::json::parse(&report.to_serve_json()).expect("valid json");
        assert_eq!(json.get("threads").and_then(|v| v.as_f64()), Some(2.0));
    }

    #[test]
    fn serve_accepts_resilient_prefix_and_reports_resilience_line() {
        let report = serve_once(2, 1, "resilient:eager", 2).expect("serve");
        assert_eq!(report.errors, 0, "failures: {:?}", report.failures);
        assert_eq!(report.dead_threads, 0);
        let text = report.render();
        assert!(text.contains("backend=resilient:eager"), "{}", text);
        assert!(text.contains("resilience: retries=0"), "{}", text);
        assert!(text.contains("supervision: sheds=0"), "{}", text);
        let json = crate::api::json::parse(&report.to_serve_json()).expect("valid json");
        assert_eq!(json.get("dead_threads").and_then(|v| v.as_f64()), Some(0.0));
        assert_eq!(json.get("sheds").and_then(|v| v.as_f64()), Some(0.0));
        assert_eq!(json.get("queue_depth_p99").and_then(|v| v.as_f64()), Some(0.0));
    }

    #[test]
    fn overloaded_shed_serve_still_returns_correct_outputs() {
        // 2x+ overload against a deliberately starved fleet: one worker,
        // a queue of one, shed admission. Every shed request must still
        // come back bitwise-correct through the eager degrade path, so
        // the run reports zero errors no matter how many calls were shed.
        let tuning = ServeTuning {
            admission: AdmissionPolicy::Shed,
            queue_cap: 1,
            workers: 1,
            ..ServeTuning::default()
        };
        let report = serve_once_tuned(6, 2, "async:eager", 3, tuning).expect("serve");
        assert_eq!(report.errors, 0, "failures: {:?}", report.failures);
        assert_eq!(report.dead_threads, 0);
        assert_eq!(report.case_runs, 6 * 2 * 3);
        // A shed is never retried, only degraded: in a run whose only
        // error source is admission control, every shed is exactly one
        // degraded call.
        assert_eq!(
            report.metrics.sheds, report.metrics.degraded_calls,
            "each shed must degrade exactly once"
        );
        assert_eq!(report.metrics.retries, 0, "Overloaded must not be retried");
    }

    #[test]
    fn serve_with_deadline_stays_correct_and_counts_aborts() {
        // A generous request deadline: nothing should expire, the run
        // stays clean, and the supervision summary renders.
        let tuning = ServeTuning { deadline_ms: Some(30_000), ..ServeTuning::default() };
        let report = serve_once_tuned(2, 1, "async:eager", 3, tuning).expect("serve");
        assert_eq!(report.errors, 0, "failures: {:?}", report.failures);
        let text = report.render();
        assert!(text.contains("supervision:"), "{}", text);
        let json = crate::api::json::parse(&report.to_serve_json()).expect("valid json");
        assert!(json.get("deadline_propagated_aborts").is_some());
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert!(percentile(&v, 0.5) >= 2.0);
    }
}
