//! The `async` backend wrapper: [`Capabilities::ASYNC`] made real.
//!
//! `AsyncBackend` decorates any inner backend; the modules it lowers
//! expose [`AsyncModule::submit`], which queues the call on a small
//! [`WorkerPool`] and immediately returns a [`CallFuture`]. The plain
//! [`CompiledModule::call`] contract is preserved as submit-then-wait, so
//! an async-wrapped backend drops into every existing dispatch path
//! (dynamo guard entries, `depyf run`, the conformance harness)
//! unchanged — callers that *want* overlap use `submit` and hold several
//! futures in flight.
//!
//! The pool is lazy: registering the builtin `async` backend must not
//! spawn threads, so workers start on the first lowered module.

use std::rc::Rc;
use std::sync::{Arc, OnceLock};

use crate::api::{
    Backend, Capabilities, CompilePlan, CompileRequest, CompiledModule, DepyfError, ModuleArtifact,
    ModuleStats,
};
use crate::tensor::Tensor;

use super::future::{call_channel, CallFuture, WorkerPool};

/// Default worker count for the shared call pool.
pub const DEFAULT_WORKERS: usize = 4;

/// Wraps an inner backend; every lowered module calls through a worker
/// pool and can return futures instead of blocking.
pub struct AsyncBackend {
    inner: Arc<dyn Backend>,
    workers: usize,
    /// Spawned on first `lower`, shared by every module of this backend.
    pool: OnceLock<Arc<WorkerPool>>,
}

impl AsyncBackend {
    pub fn new(inner: Arc<dyn Backend>) -> AsyncBackend {
        AsyncBackend::with_workers(inner, DEFAULT_WORKERS)
    }

    /// Size the worker pool explicitly (rounded up to 1).
    pub fn with_workers(inner: Arc<dyn Backend>, workers: usize) -> AsyncBackend {
        AsyncBackend { inner, workers: workers.max(1), pool: OnceLock::new() }
    }

    /// Wrap a registered backend, looked up by name (`async:<name>`).
    pub fn wrapping(inner_name: &str) -> Result<AsyncBackend, DepyfError> {
        let inner = crate::api::lookup_backend(inner_name).ok_or_else(|| {
            DepyfError::Backend(format!(
                "async: unknown inner backend '{}' (registered: {})",
                inner_name,
                crate::api::backend_names().join(", ")
            ))
        })?;
        Ok(AsyncBackend::new(inner))
    }

    /// The wrapped backend.
    pub fn inner(&self) -> &Arc<dyn Backend> {
        &self.inner
    }

    fn pool(&self) -> Arc<WorkerPool> {
        Arc::clone(self.pool.get_or_init(|| Arc::new(WorkerPool::new(self.workers))))
    }
}

impl Backend for AsyncBackend {
    fn name(&self) -> &str {
        "async"
    }

    /// Inherits the wrapped backend's capabilities, plus `ASYNC | WRAPPER`.
    fn capabilities(&self) -> Capabilities {
        self.inner.capabilities() | Capabilities::ASYNC | Capabilities::WRAPPER
    }

    fn plan(&self, req: &CompileRequest) -> Result<CompilePlan, DepyfError> {
        // Asynchrony is a dispatch-time property; the compile-time plan is
        // entirely the inner backend's.
        self.inner.plan(req)
    }

    fn lower(&self, req: &CompileRequest, plan: &CompilePlan) -> Result<Arc<dyn CompiledModule>, DepyfError> {
        let module = self.inner.lower(req, plan)?;
        Ok(Arc::new(AsyncModule {
            backend_name: format!("async({})", module.backend_name()),
            inner: module,
            pool: self.pool(),
        }))
    }
}

/// A [`CompiledModule`] whose calls run on the backend's worker pool.
pub struct AsyncModule {
    backend_name: String,
    inner: Arc<dyn CompiledModule>,
    pool: Arc<WorkerPool>,
}

impl AsyncModule {
    /// Queue a call and return immediately. Inputs are owned `Tensor`s
    /// (cheap `Arc`-data clones) because the job crosses a thread
    /// boundary; the worker rebuilds the call-local `Rc` handles the
    /// [`CompiledModule::call`] signature wants.
    pub fn submit(&self, inputs: Vec<Tensor>) -> CallFuture {
        let (promise, future) = call_channel();
        let inner = Arc::clone(&self.inner);
        self.pool.submit(Box::new(move || {
            let handles: Vec<Rc<Tensor>> = inputs.into_iter().map(Rc::new).collect();
            promise.fulfill(inner.call(&handles));
        }));
        future
    }
}

impl CompiledModule for AsyncModule {
    /// Synchronous contract: submit to the pool and wait. Identical
    /// results to the inner module, via one queue hop.
    fn call(&self, inputs: &[Rc<Tensor>]) -> Result<Vec<Tensor>, DepyfError> {
        let owned: Vec<Tensor> = inputs.iter().map(|t| (**t).clone()).collect();
        self.submit(owned).wait()
    }

    fn backend_name(&self) -> &str {
        &self.backend_name
    }

    fn artifacts(&self) -> Vec<ModuleArtifact> {
        self.inner.artifacts()
    }

    fn stats(&self) -> ModuleStats {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::EagerBackend;
    use crate::graph::{Graph, OpKind};

    fn add_graph() -> Graph {
        let mut g = Graph::new("g");
        let a = g.placeholder("a", &[2]);
        let b = g.placeholder("b", &[2]);
        let s = g.add_op(OpKind::Add, vec![a, b]).unwrap();
        g.set_outputs(vec![s]);
        g
    }

    fn lower_async(backend: &AsyncBackend) -> Arc<dyn CompiledModule> {
        let req = CompileRequest::new("__compiled_fn_1", Arc::new(add_graph()));
        let plan = backend.plan(&req).expect("plan");
        backend.lower(&req, &plan).expect("lower")
    }

    /// Like `lower` but keeps the concrete [`AsyncModule`] so tests can
    /// reach `submit`.
    fn lower_async_concrete(backend: &AsyncBackend) -> AsyncModule {
        let req = CompileRequest::new("__compiled_fn_1", Arc::new(add_graph()));
        let plan = backend.plan(&req).expect("plan");
        let inner = backend.inner().lower(&req, &plan).expect("lower inner");
        AsyncModule {
            backend_name: format!("async({})", inner.backend_name()),
            inner,
            pool: backend.pool(),
        }
    }

    #[test]
    fn async_call_matches_eager() {
        let backend = AsyncBackend::with_workers(Arc::new(EagerBackend), 2);
        let module = lower_async(&backend);
        let a = Rc::new(Tensor::new(vec![2], vec![1.0, 2.0]));
        let b = Rc::new(Tensor::new(vec![2], vec![10.0, 20.0]));
        let out = module.call(&[a, b]).expect("call ok");
        assert_eq!(out[0].data(), &[11.0, 22.0]);
        assert_eq!(module.backend_name(), "async(eager)");
    }

    #[test]
    fn submit_overlaps_calls_in_flight() {
        let backend = AsyncBackend::with_workers(Arc::new(EagerBackend), 4);
        let module = lower_async_concrete(&backend);
        let futures: Vec<CallFuture> = (0..8)
            .map(|i| {
                module.submit(vec![
                    Tensor::new(vec![2], vec![i as f32, 1.0]),
                    Tensor::new(vec![2], vec![2.0, 3.0]),
                ])
            })
            .collect();
        for (i, f) in futures.into_iter().enumerate() {
            let out = f.wait().expect("overlapped call ok");
            assert_eq!(out[0].data(), &[i as f32 + 2.0, 4.0]);
        }
    }

    #[test]
    fn capabilities_add_async_and_wrapper() {
        let backend = AsyncBackend::new(Arc::new(EagerBackend));
        let caps = backend.capabilities();
        assert!(caps.contains(Capabilities::ASYNC));
        assert!(caps.contains(Capabilities::WRAPPER));
    }

    #[test]
    fn wrapping_unknown_backend_reports_registry() {
        let err = AsyncBackend::wrapping("nope").expect_err("unknown backend");
        let msg = format!("{}", err);
        assert!(msg.contains("async: unknown inner backend 'nope'"), "{}", msg);
        assert!(msg.contains("eager"), "{}", msg);
    }
}
