//! The `async` backend wrapper: [`Capabilities::ASYNC`] made real.
//!
//! `AsyncBackend` decorates any inner backend; the modules it lowers
//! expose [`AsyncModule::submit`], which queues the call on a shared
//! [`Supervisor`] and immediately returns a [`CallFuture`]. The plain
//! [`CompiledModule::call`] contract is preserved as submit-then-wait, so
//! an async-wrapped backend drops into every existing dispatch path
//! (dynamo guard entries, `depyf run`, the conformance harness)
//! unchanged — callers that *want* overlap use `submit` and hold several
//! futures in flight.
//!
//! Since PR 10 the workers behind a lowered module are *supervised*: the
//! queue is bounded with an explicit
//! [`AdmissionPolicy`](super::supervisor::AdmissionPolicy), a watchdog
//! kills and respawns workers whose heartbeat stalls, and the
//! per-request [`Deadline`] published by the dispatch path
//! ([`crate::serve::deadline::current_deadline`]) rides into the queue
//! with each job — admission can shed doomed work and workers abort
//! expired jobs instead of computing dead results.
//!
//! The supervisor is lazy: registering the builtin `async` backend must
//! not spawn threads, so workers start on the first lowered module.

use std::rc::Rc;
use std::sync::{Arc, OnceLock};

use crate::api::{
    Backend, Capabilities, CompilePlan, CompileRequest, CompiledModule, DepyfError, ModuleArtifact,
    ModuleStats,
};
use crate::tensor::Tensor;

use super::deadline::{current_deadline, Deadline};
use super::future::CallFuture;
use super::supervisor::{Supervisor, SupervisorConfig};

/// Default worker count for the shared call pool.
pub const DEFAULT_WORKERS: usize = 4;

/// Wraps an inner backend; every lowered module calls through a
/// supervised worker fleet and can return futures instead of blocking.
pub struct AsyncBackend {
    inner: Arc<dyn Backend>,
    cfg: SupervisorConfig,
    /// Spawned on first `lower`, shared by every module of this backend.
    supervisor: OnceLock<Arc<Supervisor>>,
}

impl AsyncBackend {
    pub fn new(inner: Arc<dyn Backend>) -> AsyncBackend {
        AsyncBackend::with_workers(inner, DEFAULT_WORKERS)
    }

    /// Size the worker fleet explicitly (rounded up to 1); default
    /// supervision tuning otherwise.
    pub fn with_workers(inner: Arc<dyn Backend>, workers: usize) -> AsyncBackend {
        AsyncBackend::with_config(
            inner,
            SupervisorConfig { workers: workers.max(1), ..SupervisorConfig::default() },
        )
    }

    /// Full supervision tuning: worker count, queue bound, admission
    /// policy, stall budget, restart budget.
    pub fn with_config(inner: Arc<dyn Backend>, cfg: SupervisorConfig) -> AsyncBackend {
        AsyncBackend { inner, cfg, supervisor: OnceLock::new() }
    }

    /// Wrap a registered backend, looked up by name (`async:<name>`).
    pub fn wrapping(inner_name: &str) -> Result<AsyncBackend, DepyfError> {
        AsyncBackend::wrapping_with(inner_name, SupervisorConfig::default())
    }

    /// [`AsyncBackend::wrapping`] with explicit supervision tuning (what
    /// the serve driver uses to apply `--admission`/`--queue-cap`/...).
    pub fn wrapping_with(inner_name: &str, cfg: SupervisorConfig) -> Result<AsyncBackend, DepyfError> {
        let inner = crate::api::lookup_backend(inner_name).ok_or_else(|| {
            DepyfError::Backend(format!(
                "async: unknown inner backend '{}' (registered: {})",
                inner_name,
                crate::api::backend_names().join(", ")
            ))
        })?;
        Ok(AsyncBackend::with_config(inner, cfg))
    }

    /// The wrapped backend.
    pub fn inner(&self) -> &Arc<dyn Backend> {
        &self.inner
    }

    /// The shared supervisor (spawned on first use). The serve driver
    /// holds this handle to drain the fleet and fold its counters into
    /// the merged report.
    pub fn supervisor(&self) -> Arc<Supervisor> {
        Arc::clone(self.supervisor.get_or_init(|| Arc::new(Supervisor::new(self.cfg))))
    }
}

impl Backend for AsyncBackend {
    fn name(&self) -> &str {
        "async"
    }

    /// Inherits the wrapped backend's capabilities, plus `ASYNC | WRAPPER`.
    fn capabilities(&self) -> Capabilities {
        self.inner.capabilities() | Capabilities::ASYNC | Capabilities::WRAPPER
    }

    fn plan(&self, req: &CompileRequest) -> Result<CompilePlan, DepyfError> {
        // Asynchrony is a dispatch-time property; the compile-time plan is
        // entirely the inner backend's.
        self.inner.plan(req)
    }

    fn lower(&self, req: &CompileRequest, plan: &CompilePlan) -> Result<Arc<dyn CompiledModule>, DepyfError> {
        let module = self.inner.lower(req, plan)?;
        Ok(Arc::new(AsyncModule {
            backend_name: format!("async({})", module.backend_name()),
            inner: module,
            supervisor: self.supervisor(),
        }))
    }
}

/// A [`CompiledModule`] whose calls run on the backend's supervised
/// worker fleet.
pub struct AsyncModule {
    backend_name: String,
    inner: Arc<dyn CompiledModule>,
    supervisor: Arc<Supervisor>,
}

impl AsyncModule {
    /// Queue a call and return immediately, stamping the submitting
    /// thread's current [`Deadline`] (if any) onto the job. Inputs are
    /// owned `Tensor`s (cheap `Arc`-data clones) because the job crosses
    /// a thread boundary; the worker rebuilds the call-local `Rc`
    /// handles the [`CompiledModule::call`] signature wants.
    pub fn submit(&self, inputs: Vec<Tensor>) -> CallFuture {
        self.submit_with_deadline(inputs, current_deadline())
    }

    /// [`AsyncModule::submit`] with an explicit deadline (or none).
    pub fn submit_with_deadline(&self, inputs: Vec<Tensor>, deadline: Option<Deadline>) -> CallFuture {
        let inner = Arc::clone(&self.inner);
        self.supervisor.submit_call(
            deadline,
            Box::new(move || {
                let handles: Vec<Rc<Tensor>> = inputs.into_iter().map(Rc::new).collect();
                inner.call(&handles)
            }),
        )
    }
}

impl CompiledModule for AsyncModule {
    /// Synchronous contract: submit to the fleet and wait. Identical
    /// results to the inner module, via one queue hop. With a published
    /// deadline the wait is bounded by the remaining budget, so a wedged
    /// fleet costs the caller at most the deadline, never a hang.
    fn call(&self, inputs: &[Rc<Tensor>]) -> Result<Vec<Tensor>, DepyfError> {
        let owned: Vec<Tensor> = inputs.iter().map(|t| (**t).clone()).collect();
        let deadline = current_deadline();
        let future = self.submit_with_deadline(owned, deadline);
        match deadline {
            Some(d) => future.wait_timeout(d.remaining()),
            None => future.wait(),
        }
    }

    fn backend_name(&self) -> &str {
        &self.backend_name
    }

    /// The dispatch path's deadline watchdog can trust this module to
    /// time itself out (bounded wait above), so no sidecar thread is
    /// needed per deadlined call.
    fn deadline_aware(&self) -> bool {
        true
    }

    fn artifacts(&self) -> Vec<ModuleArtifact> {
        self.inner.artifacts()
    }

    fn stats(&self) -> ModuleStats {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::EagerBackend;
    use crate::graph::{Graph, OpKind};
    use crate::serve::deadline::with_deadline;

    fn add_graph() -> Graph {
        let mut g = Graph::new("g");
        let a = g.placeholder("a", &[2]);
        let b = g.placeholder("b", &[2]);
        let s = g.add_op(OpKind::Add, vec![a, b]).unwrap();
        g.set_outputs(vec![s]);
        g
    }

    fn lower_async(backend: &AsyncBackend) -> Arc<dyn CompiledModule> {
        let req = CompileRequest::new("__compiled_fn_1", Arc::new(add_graph()));
        let plan = backend.plan(&req).expect("plan");
        backend.lower(&req, &plan).expect("lower")
    }

    /// Like `lower` but keeps the concrete [`AsyncModule`] so tests can
    /// reach `submit`.
    fn lower_async_concrete(backend: &AsyncBackend) -> AsyncModule {
        let req = CompileRequest::new("__compiled_fn_1", Arc::new(add_graph()));
        let plan = backend.plan(&req).expect("plan");
        let inner = backend.inner().lower(&req, &plan).expect("lower inner");
        AsyncModule {
            backend_name: format!("async({})", inner.backend_name()),
            inner,
            supervisor: backend.supervisor(),
        }
    }

    #[test]
    fn async_call_matches_eager() {
        let backend = AsyncBackend::with_workers(Arc::new(EagerBackend), 2);
        let module = lower_async(&backend);
        let a = Rc::new(Tensor::new(vec![2], vec![1.0, 2.0]));
        let b = Rc::new(Tensor::new(vec![2], vec![10.0, 20.0]));
        let out = module.call(&[a, b]).expect("call ok");
        assert_eq!(out[0].data(), &[11.0, 22.0]);
        assert_eq!(module.backend_name(), "async(eager)");
        assert!(module.deadline_aware());
    }

    #[test]
    fn submit_overlaps_calls_in_flight() {
        let backend = AsyncBackend::with_workers(Arc::new(EagerBackend), 4);
        let module = lower_async_concrete(&backend);
        let futures: Vec<CallFuture> = (0..8)
            .map(|i| {
                module.submit(vec![
                    Tensor::new(vec![2], vec![i as f32, 1.0]),
                    Tensor::new(vec![2], vec![2.0, 3.0]),
                ])
            })
            .collect();
        for (i, f) in futures.into_iter().enumerate() {
            let out = f.wait().expect("overlapped call ok");
            assert_eq!(out[0].data(), &[i as f32 + 2.0, 4.0]);
        }
    }

    #[test]
    fn published_deadline_rides_into_the_call() {
        let backend = AsyncBackend::with_workers(Arc::new(EagerBackend), 1);
        let module = lower_async(&backend);
        let a = Rc::new(Tensor::new(vec![2], vec![1.0, 2.0]));
        let b = Rc::new(Tensor::new(vec![2], vec![3.0, 4.0]));
        // A healthy fleet beats a generous deadline.
        let out = with_deadline(Deadline::in_ms(10_000), || module.call(&[a, b]))
            .expect("fast call beats deadline");
        assert_eq!(out[0].data(), &[4.0, 6.0]);
        // An exhausted deadline fails typed instead of computing: either
        // the bounded wait times out or the worker aborts at dequeue.
        let a = Rc::new(Tensor::new(vec![2], vec![1.0, 2.0]));
        let b = Rc::new(Tensor::new(vec![2], vec![3.0, 4.0]));
        let err = with_deadline(Deadline::after(std::time::Duration::ZERO), || {
            module.call(&[a, b])
        })
        .expect_err("expired deadline cannot succeed");
        assert_eq!(err.layer(), "timeout");
    }

    #[test]
    fn capabilities_add_async_and_wrapper() {
        let backend = AsyncBackend::new(Arc::new(EagerBackend));
        let caps = backend.capabilities();
        assert!(caps.contains(Capabilities::ASYNC));
        assert!(caps.contains(Capabilities::WRAPPER));
    }

    #[test]
    fn wrapping_unknown_backend_reports_registry() {
        let err = AsyncBackend::wrapping("nope").expect_err("unknown backend");
        let msg = format!("{}", err);
        assert!(msg.contains("async: unknown inner backend 'nope'"), "{}", msg);
        assert!(msg.contains("eager"), "{}", msg);
    }
}
