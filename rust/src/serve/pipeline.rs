//! Pipelined execution of the sharded backend's partition plan.
//!
//! The stock `ShardedModule` runs its partitions sequentially inside one
//! `call`. [`PipelinedShardedModule`] gives every partition its own
//! *stage thread*, chained by channels: a call's environment packet flows
//! stage 0 → 1 → … → k, so **shard k of call i overlaps shard k+1 of
//! call i−1** — classic pipeline parallelism across in-flight calls.
//! Single-call latency is unchanged (the stages of one call still run in
//! order); throughput under concurrent submitters approaches
//! `1 / slowest_stage` instead of `1 / sum(stages)`.
//!
//! The environment-threading semantics are exactly
//! `Stitcher::run`'s: an `env` vector indexed by original-graph node ids,
//! seeded with the call inputs (and const graph outputs), with each stage
//! gathering `part.inputs` and scattering `part.outputs`. The only
//! difference is that tensors cross stage boundaries as owned `Tensor`s
//! (cheap `Arc`-data clones) instead of call-local `Rc`s.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::rc::Rc;
use std::sync::{mpsc, Arc, Mutex, PoisonError};
use std::thread::JoinHandle;

use crate::api::{
    ArtifactKind, Backend, Capabilities, CompilePlan, CompileRequest, CompiledModule, DepyfError,
    ModuleArtifact, ModuleStats,
};
use crate::backend::partition::{Partition, Stitcher};
use crate::backend::sharded::ShardedBackend;
use crate::graph::{Graph, NodeKind};
use crate::tensor::Tensor;

use super::deadline::{current_deadline, note_deadline_abort, Deadline};
use super::future::{call_channel, CallFuture, CallPromise};

/// The sharded backend with stage-threaded modules. Registered as
/// `pipelined`; plans exactly like `sharded` (same partitioner, same
/// per-shard compile cache), differs only in how a module dispatches.
pub struct PipelinedShardedBackend {
    inner: ShardedBackend,
}

impl Default for PipelinedShardedBackend {
    fn default() -> Self {
        PipelinedShardedBackend::new()
    }
}

impl PipelinedShardedBackend {
    pub fn new() -> PipelinedShardedBackend {
        PipelinedShardedBackend { inner: ShardedBackend::new() }
    }

    /// Cap partition size (forwarded to the sharded partitioner) — small
    /// caps make deep pipelines, useful in tests.
    pub fn with_max_ops(max_ops: usize) -> PipelinedShardedBackend {
        PipelinedShardedBackend { inner: ShardedBackend::with_max_ops(max_ops) }
    }
}

impl Backend for PipelinedShardedBackend {
    fn name(&self) -> &str {
        "pipelined"
    }

    fn capabilities(&self) -> Capabilities {
        self.inner.capabilities() | Capabilities::ASYNC
    }

    fn plan(&self, req: &CompileRequest) -> Result<CompilePlan, DepyfError> {
        self.inner.plan(req)
    }

    fn lower(&self, req: &CompileRequest, plan: &CompilePlan) -> Result<Arc<dyn CompiledModule>, DepyfError> {
        let (stitcher, cache_hits) = self.inner.lower_stitcher(req, plan)?;
        Ok(Arc::new(PipelinedShardedModule::new(&req.name, &stitcher, plan.to_json(), cache_hits)))
    }
}

/// One in-flight call: the shared environment plus the promise to resolve
/// when the last stage finishes. The submitting thread's deadline (if
/// any) rides along so every stage can abort an already-dead packet
/// instead of computing results nobody will read.
struct Pkt {
    env: Vec<Option<Tensor>>,
    promise: CallPromise,
    deadline: Option<Deadline>,
}

/// A [`CompiledModule`] that executes the sharded partition chain on
/// dedicated stage threads, one channel hop per partition boundary.
pub struct PipelinedShardedModule {
    name: String,
    graph: Arc<Graph>,
    plan_json: String,
    cache_hits: u64,
    /// Kept for `artifacts()`; the execution copies live on the stages.
    part_modules: Vec<Arc<dyn CompiledModule>>,
    /// `None` for the degenerate zero-partition plan (const/passthrough
    /// graphs): those calls are answered inline.
    sender: Mutex<Option<mpsc::Sender<Pkt>>>,
    stages: Vec<JoinHandle<()>>,
}

impl PipelinedShardedModule {
    /// Build the stage chain from a lowered stitcher. Partitions and
    /// module handles are cloned out of it; the stitcher itself is left
    /// usable (the plain sharded path and tests reuse it).
    pub fn new(name: &str, stitcher: &Stitcher, plan_json: String, cache_hits: u64) -> PipelinedShardedModule {
        let graph = Arc::clone(stitcher.graph());
        let part_modules: Vec<Arc<dyn CompiledModule>> =
            stitcher.parts().iter().map(|sp| Arc::clone(&sp.module)).collect();
        let n = stitcher.parts().len();
        if n == 0 {
            return PipelinedShardedModule {
                name: name.to_string(),
                graph,
                plan_json,
                cache_hits,
                part_modules,
                sender: Mutex::new(None),
                stages: Vec::new(),
            };
        }
        let (first_tx, mut prev_rx) = mpsc::channel::<Pkt>();
        let mut stages = Vec::with_capacity(n);
        for (k, sp) in stitcher.parts().iter().enumerate() {
            let part = sp.part.clone();
            let module = Arc::clone(&sp.module);
            let graph = Arc::clone(&graph);
            let last = k + 1 == n;
            let (next_tx, next_rx) = if last {
                (None, None)
            } else {
                let (tx, rx) = mpsc::channel::<Pkt>();
                (Some(tx), Some(rx))
            };
            let rx = prev_rx;
            let handle = std::thread::Builder::new()
                .name(format!("depyf-stage-{}", k))
                .spawn(move || stage_loop(rx, k, part, module, next_tx, graph))
                .expect("spawn pipeline stage");
            stages.push(handle);
            prev_rx = match next_rx {
                Some(rx) => rx,
                None => break,
            };
        }
        PipelinedShardedModule {
            name: name.to_string(),
            graph,
            plan_json,
            cache_hits,
            part_modules,
            sender: Mutex::new(Some(first_tx)),
            stages,
        }
    }

    /// Seed the environment the way `Stitcher::run` does: call inputs on
    /// `graph.inputs`, const graph outputs pre-materialized.
    fn build_env(&self, inputs: &[Rc<Tensor>]) -> Result<Vec<Option<Tensor>>, DepyfError> {
        let g = &*self.graph;
        g.check_inputs(inputs)?;
        let mut env: Vec<Option<Tensor>> = vec![None; g.nodes.len()];
        for (&slot, input) in g.inputs.iter().zip(inputs.iter()) {
            env[slot] = Some((**input).clone());
        }
        for &o in &g.outputs {
            match &g.nodes[o].kind {
                NodeKind::ConstScalar(v) => env[o] = Some(Tensor::scalar(*v as f32)),
                NodeKind::ConstTensor(t) => env[o] = Some(t.clone()),
                _ => {}
            }
        }
        Ok(env)
    }

    /// Inject a call into the pipeline and return immediately. Calls
    /// submitted from one thread resolve in submission order (stages are
    /// FIFO channels). The submitter's published [`Deadline`] (if any)
    /// is stamped onto the packet here, while we are still on the
    /// caller's thread.
    pub fn submit(&self, inputs: &[Rc<Tensor>]) -> CallFuture {
        let (promise, future) = call_channel();
        let env = match self.build_env(inputs) {
            Ok(env) => env,
            Err(e) => {
                promise.fulfill(Err(e));
                return future;
            }
        };
        let deadline = current_deadline();
        let sender = self.sender.lock().unwrap_or_else(PoisonError::into_inner);
        match &*sender {
            Some(tx) => {
                // A failed send drops the Pkt — its promise then resolves
                // the future with the shutdown error.
                let _ = tx.send(Pkt { env, promise, deadline });
            }
            None => {
                // Zero partitions: every output is already in the env.
                promise.fulfill(collect_outputs(&self.graph, &env));
            }
        }
        future
    }

    /// Stage-thread count (== partitions).
    pub fn depth(&self) -> usize {
        self.stages.len()
    }
}

/// Gather `graph.outputs` from a finished environment.
fn collect_outputs(graph: &Graph, env: &[Option<Tensor>]) -> Result<Vec<Tensor>, DepyfError> {
    graph
        .outputs
        .iter()
        .map(|&o| {
            env[o]
                .clone()
                .ok_or_else(|| DepyfError::Backend(format!("pipeline: output {} unevaluated", o)))
        })
        .collect()
}

/// Body of one stage thread: receive a packet, run this partition over
/// it, forward (or resolve, on the last stage). Any error resolves the
/// packet's promise immediately — later stages never see it. The per-
/// packet work (including the `pipeline.stage` fault site) runs under
/// `catch_unwind`: a panicking partition fails *that packet*, not the
/// stage thread — a dead stage would deadlock every later in-flight call.
fn stage_loop(
    rx: mpsc::Receiver<Pkt>,
    stage: usize,
    part: Partition,
    module: Arc<dyn CompiledModule>,
    next: Option<mpsc::Sender<Pkt>>,
    graph: Arc<Graph>,
) {
    while let Ok(mut pkt) = rx.recv() {
        // A packet whose deadline expired in an upstream queue is dead:
        // abort it here instead of spending this stage (and every later
        // one) computing results the caller stopped waiting for.
        if let Some(d) = pkt.deadline {
            if d.expired() {
                note_deadline_abort();
                pkt.promise.fulfill(Err(DepyfError::Timeout(format!(
                    "pipeline stage {}: packet deadline exhausted; aborting before compute",
                    stage
                ))));
                continue;
            }
        }
        // AssertUnwindSafe: the closure only reads pkt.env and shared
        // module state, and every lock below recovers from poison.
        let ran = catch_unwind(AssertUnwindSafe(|| {
            crate::faults::gate(crate::faults::Site::PipelineStage)?;
            let ins: Vec<Rc<Tensor>> = part
                .inputs
                .iter()
                .map(|&id| {
                    pkt.env[id].clone().map(Rc::new).ok_or_else(|| {
                        DepyfError::Backend(format!("pipeline: partition input {} unevaluated", id))
                    })
                })
                .collect::<Result<_, _>>()?;
            module.call(&ins)
        }));
        let outcome = ran.unwrap_or_else(|payload| {
            Err(DepyfError::from_panic(&format!("pipeline stage {}", stage), payload))
        });
        match outcome {
            Ok(outs) if outs.len() == part.outputs.len() => {
                for (&id, t) in part.outputs.iter().zip(outs.into_iter()) {
                    pkt.env[id] = Some(t);
                }
                match &next {
                    Some(tx) => {
                        let _ = tx.send(pkt);
                    }
                    None => {
                        let result = collect_outputs(&graph, &pkt.env);
                        pkt.promise.fulfill(result);
                    }
                }
            }
            Ok(outs) => pkt.promise.fulfill(Err(DepyfError::Backend(format!(
                "pipeline: partition returned {} outputs, expected {}",
                outs.len(),
                part.outputs.len()
            )))),
            Err(e) => pkt.promise.fulfill(Err(e)),
        }
    }
    // rx closed: previous stage (or the module) is shutting down. Dropping
    // `next` cascades the shutdown forward.
}

impl CompiledModule for PipelinedShardedModule {
    /// Synchronous contract: one packet through the whole pipeline. With
    /// a published deadline the wait is bounded by the remaining budget,
    /// so a wedged stage costs the caller at most the deadline.
    fn call(&self, inputs: &[Rc<Tensor>]) -> Result<Vec<Tensor>, DepyfError> {
        let future = self.submit(inputs);
        match current_deadline() {
            Some(d) => future.wait_timeout(d.remaining()),
            None => future.wait(),
        }
    }

    fn backend_name(&self) -> &str {
        "sharded+pipelined"
    }

    /// The module bounds its own calls when a deadline is published
    /// (stamped packets + bounded wait), so the dispatch path need not
    /// spawn a sidecar watchdog thread per deadlined call.
    fn deadline_aware(&self) -> bool {
        true
    }

    fn artifacts(&self) -> Vec<ModuleArtifact> {
        let mut arts = vec![ModuleArtifact {
            kind: ArtifactKind::Plan,
            name: self.name.clone(),
            file: format!("__plan_{}.json", crate::backend::sanitize(&self.name)),
            content: self.plan_json.clone(),
        }];
        for module in &self.part_modules {
            arts.extend(module.artifacts());
        }
        arts
    }

    fn stats(&self) -> ModuleStats {
        ModuleStats {
            partitions: self.part_modules.len() as u64,
            bucket: None,
            cache_hits: self.cache_hits,
        }
    }
}

impl Drop for PipelinedShardedModule {
    fn drop(&mut self) {
        // Close the intake; each stage drains, drops its forward sender,
        // and the shutdown cascades down the chain.
        self.sender.lock().unwrap_or_else(PoisonError::into_inner).take();
        for stage in self.stages.drain(..) {
            let _ = stage.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;
    use crate::tensor::Rng;

    fn deep_chain(depth: usize) -> Graph {
        let mut g = Graph::new("chain");
        let x = g.placeholder("x", &[3, 5]);
        let mut cur = x;
        for i in 0..depth {
            cur = match i % 3 {
                0 => g.add_op(OpKind::Relu, vec![cur]).unwrap(),
                1 => g.add_op(OpKind::Tanh, vec![cur]).unwrap(),
                _ => g.add_op(OpKind::Gelu, vec![cur]).unwrap(),
            };
        }
        let s = g.add_op(OpKind::Sum(None), vec![cur]).unwrap();
        g.set_outputs(vec![s]);
        g
    }

    fn lower_pair(g: Graph, max_ops: usize) -> (Arc<dyn CompiledModule>, Arc<dyn CompiledModule>) {
        let graph = Arc::new(g);
        let sharded = ShardedBackend::with_max_ops(max_ops);
        let req = CompileRequest::new("__compiled_fn_1", Arc::clone(&graph));
        let plan = sharded.plan(&req).expect("plan");
        let sequential = sharded.lower(&req, &plan).expect("sharded lower");
        let pipelined_backend = PipelinedShardedBackend::with_max_ops(max_ops);
        let plan2 = pipelined_backend.plan(&req).expect("plan2");
        let pipelined = pipelined_backend.lower(&req, &plan2).expect("pipelined lower");
        (sequential, pipelined)
    }

    #[test]
    fn pipelined_matches_sequential_sharded_bitwise() {
        let (sequential, pipelined) = lower_pair(deep_chain(9), 2);
        let mut rng = Rng::new(7);
        for _ in 0..5 {
            let x = Rc::new(Tensor::randn(&[3, 5], &mut rng));
            let want = sequential.call(&[Rc::clone(&x)]).expect("sequential");
            let got = pipelined.call(&[x]).expect("pipelined");
            assert_eq!(want.len(), got.len());
            for (w, g) in want.iter().zip(got.iter()) {
                assert_eq!(w.shape(), g.shape());
                assert_eq!(w.data(), g.data(), "pipelined output must be bitwise equal");
            }
        }
    }

    #[test]
    fn overlapped_submissions_resolve_in_order() {
        let graph = Arc::new(deep_chain(6));
        let backend = PipelinedShardedBackend::with_max_ops(1);
        let req = CompileRequest::new("__compiled_fn_2", Arc::clone(&graph));
        let plan = backend.plan(&req).expect("plan");
        let (stitcher, hits) = ShardedBackend::with_max_ops(1).lower_stitcher(&req, &plan).expect("stitch");
        let module = PipelinedShardedModule::new("__compiled_fn_2", &stitcher, plan.to_json(), hits);
        assert!(module.depth() >= 2, "want a real pipeline, got depth {}", module.depth());
        let mut rng = Rng::new(11);
        let inputs: Vec<Rc<Tensor>> =
            (0..6).map(|_| Rc::new(Tensor::randn(&[3, 5], &mut rng))).collect();
        // All six calls in flight at once, crossing stages concurrently.
        let futures: Vec<CallFuture> = inputs.iter().map(|x| module.submit(&[Rc::clone(x)])).collect();
        for (x, f) in inputs.iter().zip(futures.into_iter()) {
            let want = stitcher.run(&[Rc::clone(x)]).expect("reference");
            let got = f.wait().expect("pipelined");
            assert_eq!(want[0].data(), got[0].data());
        }
    }

    #[test]
    fn input_arity_error_resolves_future() {
        let (_, pipelined) = lower_pair(deep_chain(3), 2);
        let err = pipelined.call(&[]).expect_err("missing input must error");
        assert!(!format!("{}", err).is_empty());
    }

    #[test]
    fn drop_with_no_calls_terminates_stages() {
        let (_, pipelined) = lower_pair(deep_chain(5), 1);
        drop(pipelined); // must join stage threads, not hang
    }

    #[test]
    fn expired_deadline_aborts_the_stage_chain() {
        use crate::serve::deadline::{deadline_abort_count, with_deadline};
        let (_, pipelined) = lower_pair(deep_chain(6), 1);
        assert!(pipelined.deadline_aware());
        let mut rng = Rng::new(3);
        let x = Rc::new(Tensor::randn(&[3, 5], &mut rng));
        // A generous budget completes normally.
        let out = with_deadline(Deadline::in_ms(10_000), || pipelined.call(&[Rc::clone(&x)]))
            .expect("healthy pipeline beats a generous deadline");
        assert_eq!(out.len(), 1);
        // An exhausted budget aborts at the first stage instead of
        // flowing dead work through the whole chain.
        let before = deadline_abort_count();
        let err = with_deadline(Deadline::after(std::time::Duration::ZERO), || {
            pipelined.call(&[Rc::clone(&x)])
        })
        .expect_err("expired deadline cannot succeed");
        assert_eq!(err.layer(), "timeout");
        // The caller's bounded wait can return before the stage thread
        // dequeues the dead packet; give the abort a moment to land.
        for _ in 0..200 {
            if deadline_abort_count() > before {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(
            deadline_abort_count() > before,
            "stage abort must account to the propagated-abort counter"
        );
    }
}
