//! Worker supervision and admission control for the serving stack.
//!
//! [`WorkerPool`](super::WorkerPool) runs jobs; a [`Supervisor`] keeps the
//! *system* healthy while it does. Three mechanisms, all observable
//! through the `sheds` / `respawns` / `watchdog_kills` / `queue_depth_p99`
//! metrics:
//!
//! 1. **Heartbeats + watchdog.** Every worker stamps an atomic heartbeat
//!    when it starts a job. A watchdog thread scans the fleet on a short
//!    tick: a busy worker whose heartbeat is older than the stall budget
//!    ([`SupervisorConfig::stall_ms`]) is marked *lost*, its in-flight
//!    call is resolved out from under it with a typed transient error
//!    (first write wins — see `CallResolver` — so the caller degrades to
//!    eager instead of hanging), the wedged thread is detached, and a
//!    replacement is spawned under a restart budget with doubling
//!    backoff. Past [`SupervisorConfig::max_restarts`] the supervisor
//!    gives up: queued jobs are flushed with a typed [`DepyfError`] and
//!    new submissions are rejected, so a crash-looping fleet fails fast
//!    instead of flapping forever.
//!
//! 2. **Bounded queue + admission policy.** The shared queue holds at
//!    most [`SupervisorConfig::queue_cap`] jobs. On overflow,
//!    [`AdmissionPolicy::Block`] applies backpressure (the submitter
//!    waits), [`AdmissionPolicy::Shed`] rejects immediately with
//!    [`DepyfError::Overloaded`] (deliberately *not* transient — the
//!    dispatch path maps it straight to the eager fallback, which is the
//!    correct response to overload), and [`AdmissionPolicy::DeadlineAware`]
//!    additionally sheds any job whose remaining deadline cannot cover
//!    the observed p50 service time — work that would time out anyway is
//!    refused while it is still cheap to refuse.
//!
//! 3. **Deadlines in the queue.** Jobs carry an optional
//!    [`Deadline`]; a worker dequeuing an already-expired job aborts it
//!    with `DepyfError::Timeout` (counted as a deadline-propagated
//!    abort) instead of computing a result nobody is waiting for.
//!
//! Two fault sites make this testable: `worker.heartbeat` fires inside
//! the per-job work (a `delay` wedges the job past the stall budget, so
//! chaos rounds reconcile `fired == watchdog_kills == respawns` exactly;
//! an `error` simulates a mid-job crash), and `serve.admission` forces a
//! shed at admission (`fired == sheds`).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::api::DepyfError;
use crate::metrics::MetricsSnapshot;
use crate::serve::deadline::{note_deadline_abort, Deadline};
use crate::serve::future::{call_channel, CallFuture, CallPromise, CallResolver};
use crate::tensor::Tensor;

/// What the supervisor does when a submission finds the queue full.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Backpressure: the submitting thread waits for a slot. No request
    /// is ever refused, at the cost of caller latency under overload.
    #[default]
    Block,
    /// Fail fast: reject with [`DepyfError::Overloaded`] so the caller's
    /// dispatch path degrades to its eager fallback immediately.
    Shed,
    /// [`AdmissionPolicy::Shed`] on overflow, plus: shed any job whose
    /// remaining [`Deadline`] is below the observed p50 service time —
    /// it would time out in the queue, so refuse it while refusal is
    /// still cheap.
    DeadlineAware,
}

impl AdmissionPolicy {
    pub fn as_str(self) -> &'static str {
        match self {
            AdmissionPolicy::Block => "block",
            AdmissionPolicy::Shed => "shed",
            AdmissionPolicy::DeadlineAware => "deadline-aware",
        }
    }

    /// Parse the CLI spelling (`--admission block|shed|deadline-aware`).
    pub fn parse(s: &str) -> Option<AdmissionPolicy> {
        match s {
            "block" => Some(AdmissionPolicy::Block),
            "shed" => Some(AdmissionPolicy::Shed),
            "deadline-aware" | "deadline" => Some(AdmissionPolicy::DeadlineAware),
            _ => None,
        }
    }
}

/// Tuning for a [`Supervisor`]. The defaults suit the in-process serve
/// driver; chaos tests shrink the stall budget to provoke the watchdog.
#[derive(Clone, Copy, Debug)]
pub struct SupervisorConfig {
    /// Worker threads (min 1).
    pub workers: usize,
    /// Bounded queue capacity (min 1).
    pub queue_cap: usize,
    pub policy: AdmissionPolicy,
    /// Heartbeat stall budget in ms: a busy worker silent this long is
    /// considered wedged and killed.
    pub stall_ms: u64,
    /// Give-up threshold: total respawns allowed before the supervisor
    /// stops replacing workers and rejects new work.
    pub max_restarts: u32,
    /// Base respawn backoff in ms; doubles per restart (capped) so a
    /// crash loop cannot hot-spin the watchdog.
    pub restart_backoff_ms: u64,
}

impl Default for SupervisorConfig {
    fn default() -> SupervisorConfig {
        SupervisorConfig {
            workers: 4,
            queue_cap: 64,
            policy: AdmissionPolicy::Block,
            stall_ms: 1_000,
            max_restarts: 8,
            restart_backoff_ms: 1,
        }
    }
}

/// A supervised job's work: produces the call result the promise carries.
pub type CallWork = Box<dyn FnOnce() -> Result<Vec<Tensor>, DepyfError> + Send + 'static>;

struct SupJob {
    work: CallWork,
    deadline: Option<Deadline>,
    promise: CallPromise,
}

struct QueueState {
    jobs: VecDeque<SupJob>,
    draining: bool,
    shutdown: bool,
}

/// Last-N service times (µs) backing the DeadlineAware p50 estimate.
struct ServiceRing {
    samples: Vec<u64>,
    next: usize,
}

impl ServiceRing {
    const CAP: usize = 64;

    fn new() -> ServiceRing {
        ServiceRing { samples: Vec::with_capacity(ServiceRing::CAP), next: 0 }
    }

    fn record(&mut self, us: u64) {
        if self.samples.len() < ServiceRing::CAP {
            self.samples.push(us);
        } else {
            self.samples[self.next] = us;
            self.next = (self.next + 1) % ServiceRing::CAP;
        }
    }

    fn p50(&self) -> Duration {
        if self.samples.is_empty() {
            return Duration::ZERO;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        Duration::from_micros(sorted[sorted.len() / 2])
    }
}

struct Shared {
    cfg: SupervisorConfig,
    q: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    sheds: AtomicU64,
    kills: AtomicU64,
    respawns: AtomicU64,
    restarts: AtomicU64,
    gave_up: AtomicBool,
    /// Histogram of queue depth sampled after each enqueue; index =
    /// depth (1..=cap), slot 0 unused by enqueue sampling.
    depth_hist: Vec<AtomicU64>,
    service: Mutex<ServiceRing>,
    epoch: Instant,
}

impl Shared {
    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    fn record_service(&self, elapsed: Duration) {
        let mut ring = self.service.lock().unwrap_or_else(PoisonError::into_inner);
        ring.record(elapsed.as_micros() as u64);
    }

    fn service_p50(&self) -> Duration {
        self.service.lock().unwrap_or_else(PoisonError::into_inner).p50()
    }

    /// Nearest-rank p99 over the per-enqueue depth samples.
    fn queue_depth_p99(&self) -> u64 {
        let counts: Vec<u64> = self.depth_hist.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let rank = ((total * 99 + 99) / 100).max(1); // nearest-rank ceil
        let mut seen = 0u64;
        for (depth, count) in counts.iter().enumerate() {
            seen += count;
            if seen >= rank {
                return depth as u64;
            }
        }
        (counts.len() - 1) as u64
    }
}

/// Per-worker state shared between the worker thread and the watchdog.
struct WorkerState {
    busy: AtomicBool,
    /// ms since the supervisor's epoch, stamped at job start.
    heartbeat_ms: AtomicU64,
    /// Set by the watchdog: this worker was abandoned; it must exit at
    /// the next loop edge because a replacement now owns its slot.
    lost: AtomicBool,
    /// The in-flight call's out-of-band resolver, published for the
    /// duration of the job so the watchdog can abandon it.
    resolver: Mutex<Option<CallResolver>>,
}

impl WorkerState {
    fn new() -> WorkerState {
        WorkerState {
            busy: AtomicBool::new(false),
            heartbeat_ms: AtomicU64::new(0),
            lost: AtomicBool::new(false),
            resolver: Mutex::new(None),
        }
    }
}

struct WorkerEntry {
    state: Arc<WorkerState>,
    /// `None` once the watchdog detached a wedged thread (it exits on its
    /// own when — if — the stuck job returns) or after a join.
    handle: Option<JoinHandle<()>>,
    generation: u64,
}

/// Counter snapshot for reports; see module docs for what each means.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SupervisorSnapshot {
    pub sheds: u64,
    pub respawns: u64,
    pub watchdog_kills: u64,
    pub queue_depth_p99: u64,
    pub gave_up: bool,
}

impl SupervisorSnapshot {
    /// Accumulate into a metrics snapshot (depth is a gauge → max).
    pub fn fold_into(&self, m: &mut MetricsSnapshot) {
        m.sheds += self.sheds;
        m.respawns += self.respawns;
        m.watchdog_kills += self.watchdog_kills;
        m.queue_depth_p99 = m.queue_depth_p99.max(self.queue_depth_p99);
    }
}

/// The supervision layer: bounded admission in front, heartbeat-watched
/// workers behind, a watchdog respawning what wedges. See module docs.
pub struct Supervisor {
    shared: Arc<Shared>,
    slots: Arc<Mutex<Vec<WorkerEntry>>>,
    watchdog: Option<JoinHandle<()>>,
}

impl Supervisor {
    pub fn new(cfg: SupervisorConfig) -> Supervisor {
        let cfg = SupervisorConfig {
            workers: cfg.workers.max(1),
            queue_cap: cfg.queue_cap.max(1),
            stall_ms: cfg.stall_ms.max(1),
            ..cfg
        };
        let shared = Arc::new(Shared {
            q: Mutex::new(QueueState { jobs: VecDeque::new(), draining: false, shutdown: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            sheds: AtomicU64::new(0),
            kills: AtomicU64::new(0),
            respawns: AtomicU64::new(0),
            restarts: AtomicU64::new(0),
            gave_up: AtomicBool::new(false),
            depth_hist: (0..=cfg.queue_cap).map(|_| AtomicU64::new(0)).collect(),
            service: Mutex::new(ServiceRing::new()),
            epoch: Instant::now(),
            cfg,
        });
        let slots: Vec<WorkerEntry> = (0..cfg.workers)
            .map(|i| {
                let state = Arc::new(WorkerState::new());
                let handle = spawn_worker(&shared, &state, i, 0);
                WorkerEntry { state, handle: Some(handle), generation: 0 }
            })
            .collect();
        let slots = Arc::new(Mutex::new(slots));
        let watchdog = {
            let shared = Arc::clone(&shared);
            let slots = Arc::clone(&slots);
            std::thread::Builder::new()
                .name("depyf-watchdog".into())
                .spawn(move || watchdog_loop(shared, slots))
                .expect("spawn watchdog")
        };
        Supervisor { shared, slots, watchdog: Some(watchdog) }
    }

    /// Submit work under admission control; always returns a future that
    /// resolves (accepted, shed, rejected or abandoned — never a hang).
    /// `deadline` rides with the job: DeadlineAware admission consults
    /// it, and a worker dequeuing it after expiry aborts instead of
    /// computing a dead result.
    pub fn submit_call(&self, deadline: Option<Deadline>, work: CallWork) -> CallFuture {
        let (promise, future) = call_channel();
        // Same site `WorkerPool::submit` gates, same semantics: the
        // injected rejection reaches the caller as a typed transient
        // error instead of a dropped job.
        if let Err(e) = crate::faults::gate(crate::faults::Site::WorkerSubmit) {
            promise.fulfill(Err(e));
            return future;
        }
        // Forced shed: chaos rounds reconcile `fired == sheds` here.
        if crate::faults::gate(crate::faults::Site::ServeAdmission).is_err() {
            self.shed(promise, "injected admission fault");
            return future;
        }
        if self.shared.gave_up.load(Ordering::Acquire) {
            promise.fulfill(Err(self.give_up_error()));
            return future;
        }
        let cfg = &self.shared.cfg;
        let mut q = self.shared.q.lock().unwrap_or_else(PoisonError::into_inner);
        loop {
            if q.draining || q.shutdown {
                drop(q);
                promise.fulfill(Err(DepyfError::Runtime(
                    "supervisor is draining; call rejected".into(),
                )));
                return future;
            }
            if cfg.policy == AdmissionPolicy::DeadlineAware {
                if let Some(d) = deadline {
                    let p50 = self.shared.service_p50();
                    let remaining = d.remaining();
                    if remaining < p50 {
                        drop(q);
                        self.shed(
                            promise,
                            &format!(
                                "remaining deadline {:?} is below the observed p50 service time {:?}",
                                remaining, p50
                            ),
                        );
                        return future;
                    }
                }
            }
            if q.jobs.len() < cfg.queue_cap {
                break;
            }
            match cfg.policy {
                AdmissionPolicy::Block => {
                    q = self.shared.not_full.wait(q).unwrap_or_else(PoisonError::into_inner);
                }
                AdmissionPolicy::Shed | AdmissionPolicy::DeadlineAware => {
                    drop(q);
                    self.shed(promise, &format!("queue full (cap {})", cfg.queue_cap));
                    return future;
                }
            }
        }
        q.jobs.push_back(SupJob { work, deadline, promise });
        self.shared.depth_hist[q.jobs.len()].fetch_add(1, Ordering::Relaxed);
        self.shared.not_empty.notify_one();
        drop(q);
        future
    }

    fn shed(&self, promise: CallPromise, why: &str) {
        self.shared.sheds.fetch_add(1, Ordering::Relaxed);
        promise.fulfill(Err(DepyfError::Overloaded(format!(
            "request shed by admission control: {}",
            why
        ))));
    }

    fn give_up_error(&self) -> DepyfError {
        DepyfError::Backend(format!(
            "supervisor restart budget exhausted ({} respawns): workers are crash-looping; rejecting work so callers degrade",
            self.shared.cfg.max_restarts
        ))
    }

    /// Graceful shutdown: stop admitting, let workers finish queued and
    /// in-flight jobs (abandoned/lost workers excluded), join the fleet.
    /// Subsequent submissions are rejected with a typed transient error;
    /// counters stay readable, so reports merge deterministically after
    /// the drain instead of racing live workers.
    pub fn drain(&self) {
        {
            let mut q = self.shared.q.lock().unwrap_or_else(PoisonError::into_inner);
            q.draining = true;
            self.shared.not_empty.notify_all();
            self.shared.not_full.notify_all();
        }
        loop {
            let queue_empty = {
                let q = self.shared.q.lock().unwrap_or_else(PoisonError::into_inner);
                q.jobs.is_empty()
            };
            let inflight = {
                let slots = self.slots.lock().unwrap_or_else(PoisonError::into_inner);
                slots.iter().any(|e| {
                    e.state.busy.load(Ordering::Acquire) && !e.state.lost.load(Ordering::Acquire)
                })
            };
            // A kill resolves the caller *before* the (backed-off) respawn
            // lands, so also wait for the fleet to be restored — otherwise
            // a snapshot taken right after drain can read respawns < kills
            // and the chaos reconciliation would flake. Past the restart
            // budget no respawn is coming; `gave_up` settles the ledger.
            let fleet_restored = self.shared.gave_up.load(Ordering::Acquire)
                || self.shared.respawns.load(Ordering::Relaxed)
                    == self.shared.kills.load(Ordering::Relaxed);
            if queue_empty && !inflight && fleet_restored {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        let mut slots = self.slots.lock().unwrap_or_else(PoisonError::into_inner);
        for entry in slots.iter_mut() {
            if let Some(handle) = entry.handle.take() {
                let _ = handle.join();
            }
        }
    }

    pub fn snapshot(&self) -> SupervisorSnapshot {
        SupervisorSnapshot {
            sheds: self.shared.sheds.load(Ordering::Relaxed),
            respawns: self.shared.respawns.load(Ordering::Relaxed),
            watchdog_kills: self.shared.kills.load(Ordering::Relaxed),
            queue_depth_p99: self.shared.queue_depth_p99(),
            gave_up: self.shared.gave_up.load(Ordering::Acquire),
        }
    }

    pub fn config(&self) -> &SupervisorConfig {
        &self.shared.cfg
    }
}

impl Drop for Supervisor {
    fn drop(&mut self) {
        {
            let mut q = self.shared.q.lock().unwrap_or_else(PoisonError::into_inner);
            q.shutdown = true;
            self.shared.not_empty.notify_all();
            self.shared.not_full.notify_all();
        }
        let mut slots = self.slots.lock().unwrap_or_else(PoisonError::into_inner);
        for entry in slots.iter_mut() {
            if let Some(handle) = entry.handle.take() {
                let _ = handle.join();
            }
        }
        drop(slots);
        if let Some(watchdog) = self.watchdog.take() {
            let _ = watchdog.join();
        }
    }
}

fn spawn_worker(
    shared: &Arc<Shared>,
    state: &Arc<WorkerState>,
    slot: usize,
    generation: u64,
) -> JoinHandle<()> {
    let shared = Arc::clone(shared);
    let state = Arc::clone(state);
    std::thread::Builder::new()
        .name(format!("depyf-sup-{}-g{}", slot, generation))
        .spawn(move || worker_loop(shared, state))
        .expect("spawn supervised worker")
}

fn worker_loop(shared: Arc<Shared>, state: Arc<WorkerState>) {
    loop {
        let job = {
            let mut q = shared.q.lock().unwrap_or_else(PoisonError::into_inner);
            loop {
                if q.shutdown {
                    // Hard shutdown drops queued jobs; their promises'
                    // drop error resolves any remaining waiters.
                    break None;
                }
                if let Some(job) = q.jobs.pop_front() {
                    shared.not_full.notify_one();
                    break Some(job);
                }
                if q.draining {
                    break None; // drain: queue empty means we are done
                }
                q = shared.not_empty.wait(q).unwrap_or_else(PoisonError::into_inner);
            }
        };
        let Some(SupJob { work, deadline, promise }) = job else { break };
        if let Some(d) = deadline {
            if d.expired() {
                note_deadline_abort();
                promise.fulfill(Err(DepyfError::Timeout(
                    "job deadline exhausted while queued; aborted before dispatch".into(),
                )));
                continue;
            }
        }
        state.heartbeat_ms.store(shared.now_ms(), Ordering::Relaxed);
        *state.resolver.lock().unwrap_or_else(PoisonError::into_inner) = Some(promise.resolver());
        state.busy.store(true, Ordering::Release);
        let t0 = Instant::now();
        // `worker.heartbeat` fires inside the guarded region: a delay
        // wedges this job past the stall budget (the watchdog kills us),
        // an error simulates a mid-job crash, a panic exercises the
        // catch_unwind isolation below.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            crate::faults::gate(crate::faults::Site::WorkerHeartbeat)?;
            work()
        }))
        .unwrap_or_else(|payload| Err(DepyfError::from_panic("supervised worker", payload)));
        state.busy.store(false, Ordering::Release);
        *state.resolver.lock().unwrap_or_else(PoisonError::into_inner) = None;
        let lost = state.lost.load(Ordering::Acquire);
        if !lost {
            // Wedged jobs don't pollute the p50 the DeadlineAware policy
            // sheds against.
            shared.record_service(t0.elapsed());
        }
        // No-op if the watchdog already abandoned this call.
        promise.fulfill(result);
        if lost {
            break; // a replacement owns this slot now
        }
    }
}

fn watchdog_loop(shared: Arc<Shared>, slots: Arc<Mutex<Vec<WorkerEntry>>>) {
    let tick = Duration::from_millis((shared.cfg.stall_ms / 4).clamp(2, 50));
    loop {
        std::thread::sleep(tick);
        {
            let q = shared.q.lock().unwrap_or_else(PoisonError::into_inner);
            if q.shutdown {
                return;
            }
        }
        let now = shared.now_ms();
        let mut slots_guard = slots.lock().unwrap_or_else(PoisonError::into_inner);
        for (slot, entry) in slots_guard.iter_mut().enumerate() {
            let st = &entry.state;
            if !st.busy.load(Ordering::Acquire) || st.lost.load(Ordering::Acquire) {
                continue;
            }
            let stalled_for = now.saturating_sub(st.heartbeat_ms.load(Ordering::Relaxed));
            if stalled_for <= shared.cfg.stall_ms {
                continue;
            }
            // Wedged: abandon the call, detach the thread, respawn.
            st.lost.store(true, Ordering::Release);
            shared.kills.fetch_add(1, Ordering::Relaxed);
            let resolver =
                st.resolver.lock().unwrap_or_else(PoisonError::into_inner).take();
            if let Some(resolver) = resolver {
                resolver.resolve_if_pending(Err(DepyfError::Runtime(format!(
                    "supervisor abandoned the call: worker heartbeat stalled {}ms (budget {}ms); a replacement worker took the slot",
                    stalled_for, shared.cfg.stall_ms
                ))));
            }
            // Detached, not joined: the thread exits on its own when (if)
            // the stuck job ever returns; its late result is discarded by
            // first-write-wins resolution.
            entry.handle.take();
            let restarts = shared.restarts.fetch_add(1, Ordering::Relaxed) + 1;
            if restarts > shared.cfg.max_restarts as u64 {
                give_up(&shared);
                continue;
            }
            // Doubling backoff, capped: a crash loop must not hot-spin.
            let backoff = shared
                .cfg
                .restart_backoff_ms
                .saturating_mul(1u64 << (restarts - 1).min(10))
                .min(200);
            if backoff > 0 {
                std::thread::sleep(Duration::from_millis(backoff));
            }
            let state = Arc::new(WorkerState::new());
            entry.generation += 1;
            let handle = spawn_worker(&shared, &state, slot, entry.generation);
            entry.state = state;
            entry.handle = Some(handle);
            shared.respawns.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Past the restart budget: reject new work *and* flush the queue with
/// the same typed error, so jobs stranded behind dead workers resolve
/// (and degrade) instead of waiting on capacity that will never return.
fn give_up(shared: &Arc<Shared>) {
    shared.gave_up.store(true, Ordering::Release);
    let stranded: Vec<SupJob> = {
        let mut q = shared.q.lock().unwrap_or_else(PoisonError::into_inner);
        q.jobs.drain(..).collect()
    };
    for job in stranded {
        job.promise.fulfill(Err(DepyfError::Backend(format!(
            "supervisor restart budget exhausted ({} respawns): workers are crash-looping; rejecting work so callers degrade",
            shared.cfg.max_restarts
        ))));
    }
    shared.not_full.notify_all();
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn cfg(workers: usize, cap: usize, policy: AdmissionPolicy) -> SupervisorConfig {
        SupervisorConfig {
            workers,
            queue_cap: cap,
            policy,
            stall_ms: 5_000, // far away unless a test shrinks it
            ..SupervisorConfig::default()
        }
    }

    fn ok_job(v: f32) -> CallWork {
        Box::new(move || Ok(vec![Tensor::scalar(v)]))
    }

    #[test]
    fn jobs_run_and_resolve_in_order_of_submission_value() {
        let sup = Supervisor::new(cfg(2, 8, AdmissionPolicy::Block));
        let futures: Vec<CallFuture> =
            (0..8).map(|i| sup.submit_call(None, ok_job(i as f32))).collect();
        for (i, f) in futures.into_iter().enumerate() {
            assert_eq!(f.wait().expect("job ok")[0].item(), i as f32);
        }
        let snap = sup.snapshot();
        assert_eq!(snap.sheds, 0);
        assert_eq!(snap.watchdog_kills, 0);
        assert!(snap.queue_depth_p99 <= 8);
    }

    #[test]
    fn block_policy_backpressures_instead_of_shedding() {
        let sup = Supervisor::new(cfg(1, 1, AdmissionPolicy::Block));
        // One slow job occupies the worker; cap 1 queue fills behind it.
        let futures: Vec<CallFuture> = (0..4)
            .map(|i| {
                sup.submit_call(
                    None,
                    Box::new(move || {
                        std::thread::sleep(Duration::from_millis(10));
                        Ok(vec![Tensor::scalar(i as f32)])
                    }),
                )
            })
            .collect();
        for (i, f) in futures.into_iter().enumerate() {
            assert_eq!(f.wait().expect("blocked, not shed")[0].item(), i as f32);
        }
        assert_eq!(sup.snapshot().sheds, 0, "Block never sheds");
    }

    #[test]
    fn shed_policy_rejects_overflow_with_typed_overloaded() {
        let sup = Supervisor::new(cfg(1, 1, AdmissionPolicy::Shed));
        let (release_tx, release_rx) = mpsc::channel::<()>();
        let (started_tx, started_rx) = mpsc::channel::<()>();
        // A: occupies the single worker until released.
        let fut_a = sup.submit_call(
            None,
            Box::new(move || {
                started_tx.send(()).ok();
                release_rx.recv().ok();
                Ok(vec![Tensor::scalar(1.0)])
            }),
        );
        started_rx.recv().expect("worker picked up job A");
        // B: fills the cap-1 queue. C: must shed.
        let fut_b = sup.submit_call(None, ok_job(2.0));
        let fut_c = sup.submit_call(None, ok_job(3.0));
        let err = fut_c.wait().expect_err("C must be shed");
        assert_eq!(err.layer(), "overloaded");
        assert!(!err.is_transient(), "sheds must not be retried into the full queue");
        assert!(format!("{}", err).contains("queue full (cap 1)"), "{}", err);
        release_tx.send(()).expect("release job A");
        assert_eq!(fut_a.wait().expect("A completes")[0].item(), 1.0);
        assert_eq!(fut_b.wait().expect("B was queued, not shed")[0].item(), 2.0);
        let snap = sup.snapshot();
        assert_eq!(snap.sheds, 1);
        assert_eq!(snap.queue_depth_p99, 1, "cap bounds the sampled depth");
    }

    #[test]
    fn deadline_aware_sheds_doomed_jobs_but_admits_viable_ones() {
        let sup = Supervisor::new(cfg(1, 8, AdmissionPolicy::DeadlineAware));
        // Seed the service-time estimate with ~20ms jobs.
        for _ in 0..4 {
            let f = sup.submit_call(
                None,
                Box::new(|| {
                    std::thread::sleep(Duration::from_millis(20));
                    Ok(vec![Tensor::scalar(0.0)])
                }),
            );
            f.wait().expect("seeding job");
        }
        assert!(sup.shared.service_p50() >= Duration::from_millis(15));
        // 1ms of budget cannot cover a ~20ms p50: shed at admission.
        let doomed = sup.submit_call(Some(Deadline::in_ms(1)), ok_job(9.0));
        let err = doomed.wait().expect_err("doomed job must shed");
        assert_eq!(err.layer(), "overloaded");
        assert!(format!("{}", err).contains("p50"), "{}", err);
        // A generous budget is admitted and served.
        let viable = sup.submit_call(Some(Deadline::in_ms(10_000)), ok_job(4.0));
        assert_eq!(viable.wait().expect("viable job runs")[0].item(), 4.0);
        // No deadline at all is always admitted under DeadlineAware.
        let free = sup.submit_call(None, ok_job(5.0));
        assert_eq!(free.wait().expect("no-deadline job runs")[0].item(), 5.0);
        assert_eq!(sup.snapshot().sheds, 1);
    }

    #[test]
    fn watchdog_abandons_stalled_call_and_respawns_the_worker() {
        let sup = Supervisor::new(SupervisorConfig {
            stall_ms: 30,
            ..cfg(1, 4, AdmissionPolicy::Block)
        });
        let t0 = Instant::now();
        let wedged = sup.submit_call(
            None,
            Box::new(|| {
                std::thread::sleep(Duration::from_millis(600));
                Ok(vec![Tensor::scalar(-1.0)])
            }),
        );
        // Promise drop-safety via the resolver: the caller gets a typed
        // transient error well before the wedged job finishes.
        let err = wedged.wait().expect_err("watchdog must abandon the call");
        assert!(t0.elapsed() < Duration::from_millis(500), "abandoned before the job finished");
        assert_eq!(err.layer(), "runtime");
        assert!(err.is_transient(), "abandonment retries elsewhere: {}", err);
        assert!(format!("{}", err).contains("heartbeat stalled"), "{}", err);
        // The replacement worker serves the next job.
        let next = sup.submit_call(None, ok_job(7.0));
        assert_eq!(next.wait().expect("replacement worker runs")[0].item(), 7.0);
        let snap = sup.snapshot();
        assert_eq!(snap.watchdog_kills, 1);
        assert_eq!(snap.respawns, 1);
        assert!(!snap.gave_up);
    }

    #[test]
    fn restart_budget_exhaustion_gives_up_with_typed_error() {
        let sup = Supervisor::new(SupervisorConfig {
            stall_ms: 25,
            max_restarts: 1,
            ..cfg(1, 4, AdmissionPolicy::Block)
        });
        let stall_job = || -> CallWork {
            Box::new(|| {
                std::thread::sleep(Duration::from_millis(400));
                Ok(vec![])
            })
        };
        // First stall: killed and respawned (budget 1).
        assert!(sup.submit_call(None, stall_job()).wait().is_err());
        // Second stall: killed, but the budget is spent → give up.
        assert!(sup.submit_call(None, stall_job()).wait().is_err());
        // Wait for the watchdog to conclude.
        let deadline = Instant::now() + Duration::from_secs(5);
        while !sup.snapshot().gave_up && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        let snap = sup.snapshot();
        assert!(snap.gave_up, "supervisor must give up past the budget: {:?}", snap);
        assert_eq!(snap.watchdog_kills, 2);
        assert_eq!(snap.respawns, 1, "no respawn past the budget");
        let rejected = sup.submit_call(None, ok_job(1.0));
        let err = rejected.wait().expect_err("gave-up supervisor rejects work");
        assert!(format!("{}", err).contains("restart budget exhausted"), "{}", err);
    }

    #[test]
    fn drain_finishes_inflight_then_rejects_new_work() {
        let sup = Supervisor::new(cfg(2, 8, AdmissionPolicy::Block));
        let futures: Vec<CallFuture> = (0..6)
            .map(|i| {
                sup.submit_call(
                    None,
                    Box::new(move || {
                        std::thread::sleep(Duration::from_millis(5));
                        Ok(vec![Tensor::scalar(i as f32)])
                    }),
                )
            })
            .collect();
        sup.drain();
        for (i, f) in futures.into_iter().enumerate() {
            assert_eq!(f.wait().expect("in-flight finishes")[0].item(), i as f32);
        }
        let late = sup.submit_call(None, ok_job(0.0));
        let err = late.wait().expect_err("drained supervisor admits nothing");
        assert_eq!(err.layer(), "runtime");
        assert!(err.is_transient());
        assert!(format!("{}", err).contains("draining"), "{}", err);
    }

    #[test]
    fn expired_deadline_is_aborted_at_dequeue_not_computed() {
        let sup = Supervisor::new(cfg(1, 8, AdmissionPolicy::Block));
        let aborts_before = crate::serve::deadline::deadline_abort_count();
        let (release_tx, release_rx) = mpsc::channel::<()>();
        let (started_tx, started_rx) = mpsc::channel::<()>();
        let blocker = sup.submit_call(
            None,
            Box::new(move || {
                started_tx.send(()).ok();
                release_rx.recv().ok();
                Ok(vec![])
            }),
        );
        started_rx.recv().expect("worker busy");
        // 5ms of budget spent entirely behind the blocker.
        let doomed = sup.submit_call(Some(Deadline::in_ms(5)), ok_job(1.0));
        std::thread::sleep(Duration::from_millis(20));
        release_tx.send(()).expect("release blocker");
        let err = doomed.wait().expect_err("expired job must abort at dequeue");
        assert_eq!(err.layer(), "timeout");
        assert!(format!("{}", err).contains("while queued"), "{}", err);
        blocker.wait().expect("blocker ok");
        assert!(
            crate::serve::deadline::deadline_abort_count() > aborts_before,
            "abort must be counted"
        );
    }

    #[test]
    fn panicking_job_is_caught_and_worker_survives() {
        let sup = Supervisor::new(cfg(1, 4, AdmissionPolicy::Block));
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let boom = sup.submit_call(None, Box::new(|| panic!("job exploded")));
        let err = boom.wait().expect_err("panic becomes a typed error");
        std::panic::set_hook(prev);
        assert_eq!(err.layer(), "panic");
        // Same worker thread (no kill, no respawn) serves the next call.
        let next = sup.submit_call(None, ok_job(6.0));
        assert_eq!(next.wait().expect("worker survived the panic")[0].item(), 6.0);
        let snap = sup.snapshot();
        assert_eq!(snap.watchdog_kills, 0);
        assert_eq!(snap.respawns, 0);
    }

    #[test]
    fn admission_policy_parses_cli_spellings() {
        assert_eq!(AdmissionPolicy::parse("block"), Some(AdmissionPolicy::Block));
        assert_eq!(AdmissionPolicy::parse("shed"), Some(AdmissionPolicy::Shed));
        assert_eq!(AdmissionPolicy::parse("deadline-aware"), Some(AdmissionPolicy::DeadlineAware));
        assert_eq!(AdmissionPolicy::parse("deadline"), Some(AdmissionPolicy::DeadlineAware));
        assert_eq!(AdmissionPolicy::parse("drop"), None);
        assert_eq!(AdmissionPolicy::DeadlineAware.as_str(), "deadline-aware");
    }
}
