//! Crate-internal FNV-1a hashing, shared by [`crate::graph`]'s
//! `content_hash`, the guard dispatcher's constant fingerprints and the
//! runtime disk cache's file naming — one implementation, one set of
//! magic constants.

const OFFSET: u64 = 0xcbf29ce484222325;
const PRIME: u64 = 0x100000001b3;

/// Streaming FNV-1a accumulator.
pub(crate) struct Fnv(u64);

impl Fnv {
    pub(crate) fn new() -> Fnv {
        Fnv(OFFSET)
    }

    /// Hash a u64 as 8 little-endian bytes.
    pub(crate) fn num(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.0 = (self.0 ^ b as u64).wrapping_mul(PRIME);
        }
    }

    /// Hash a length-prefixed byte string.
    pub(crate) fn bytes(&mut self, bs: &[u8]) {
        self.num(bs.len() as u64);
        for b in bs {
            self.0 = (self.0 ^ *b as u64).wrapping_mul(PRIME);
        }
    }

    pub(crate) fn finish(&self) -> u64 {
        self.0
    }
}

/// One-shot hash of a string.
pub(crate) fn hash_str(s: &str) -> u64 {
    let mut h = Fnv::new();
    h.bytes(s.as_bytes());
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_distinct() {
        assert_eq!(hash_str("abc"), hash_str("abc"));
        assert_ne!(hash_str("abc"), hash_str("abd"));
        assert_ne!(hash_str(""), hash_str("\0"));
        let mut a = Fnv::new();
        a.num(1);
        let mut b = Fnv::new();
        b.num(2);
        assert_ne!(a.finish(), b.finish());
    }
}
