//! Deterministic fault injection for the dispatch path.
//!
//! The serving stack promises graceful degradation: a panicking backend,
//! a corrupt cache entry or a stuck worker must never take down the
//! fleet. This module is how that promise is *tested* — a seeded
//! [`FaultPlan`] injects errors, panics and delays at named sites along
//! the compile/dispatch path, deterministically (same seed + same hit
//! order → same faults), so the chaos suite can reconcile every injected
//! fault against the retry/degrade/timeout counters it produced.
//!
//! # Fault sites
//!
//! | site | fires inside |
//! | --- | --- |
//! | `backend.plan` | every concrete backend's `Backend::plan` |
//! | `backend.lower` | every concrete backend's `Backend::lower` |
//! | `module.call` | `CompiledGraphFn` dispatch (the compiled-call hot path) |
//! | `disk_cache.read` | `DiskCache::get` (fault → treated as a miss) |
//! | `disk_cache.write` | `DiskCache::put` (fault → write skipped) |
//! | `worker_pool.submit` | `WorkerPool::submit` (async backend futures) |
//! | `pipeline.stage` | per-packet work in each pipelined stage thread |
//! | `worker.heartbeat` | per-job work in each supervised worker (delay = a wedged job the watchdog must kill; error = a simulated mid-job crash) |
//! | `serve.admission` | `Supervisor::submit_call` admission (error = forced shed) |
//!
//! # The `DEPYF_FAULTS` spec grammar
//!
//! Clauses separated by `;`: an optional `seed=<u64>` plus any number of
//! `<site>=<kind>[@<num>/<den>]` clauses, where `<kind>` is `error`,
//! `panic` or `delay:<ms>` and `@<num>/<den>` is the firing rate
//! (default `1/1` — every hit fires). Example:
//!
//! ```text
//! DEPYF_FAULTS="seed=7;backend.plan=error@1/5;module.call=panic@1/7;pipeline.stage=delay:20@1/3"
//! ```
//!
//! Whether hit `n` at a site fires is a pure function of
//! `(seed, site, n)` — an FNV hash modulo the rate denominator — so a
//! failing chaos run is reproduced by its seed + spec alone.
//!
//! # Cost when off
//!
//! Unconfigured processes pay exactly one relaxed atomic load per gated
//! site — no locks, no allocation, no branches beyond the load. The env
//! var is consulted once, lazily, on the first gate. Programmatic
//! installation ([`install`]) returns an RAII [`FaultGuard`] that clears
//! the plan (and its counters) on drop; chaos tests install a fresh plan
//! per round so per-round counters start at zero.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Once, PoisonError, RwLock};
use std::time::Duration;

use crate::api::DepyfError;

/// A named injection point on the dispatch path.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Site {
    BackendPlan,
    BackendLower,
    ModuleCall,
    DiskCacheRead,
    DiskCacheWrite,
    WorkerSubmit,
    PipelineStage,
    WorkerHeartbeat,
    ServeAdmission,
}

/// Every site, in spec/report order.
pub const SITES: [Site; 9] = [
    Site::BackendPlan,
    Site::BackendLower,
    Site::ModuleCall,
    Site::DiskCacheRead,
    Site::DiskCacheWrite,
    Site::WorkerSubmit,
    Site::PipelineStage,
    Site::WorkerHeartbeat,
    Site::ServeAdmission,
];

impl Site {
    /// The spec-grammar name of this site.
    pub fn as_str(self) -> &'static str {
        match self {
            Site::BackendPlan => "backend.plan",
            Site::BackendLower => "backend.lower",
            Site::ModuleCall => "module.call",
            Site::DiskCacheRead => "disk_cache.read",
            Site::DiskCacheWrite => "disk_cache.write",
            Site::WorkerSubmit => "worker_pool.submit",
            Site::PipelineStage => "pipeline.stage",
            Site::WorkerHeartbeat => "worker.heartbeat",
            Site::ServeAdmission => "serve.admission",
        }
    }

    /// Inverse of [`Site::as_str`].
    pub fn parse(s: &str) -> Option<Site> {
        SITES.iter().copied().find(|site| site.as_str() == s)
    }

    fn index(self) -> usize {
        SITES.iter().position(|&s| s == self).expect("site is in SITES")
    }
}

impl std::fmt::Display for Site {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// What an armed site does when a hit fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Return [`DepyfError::Fault`] from the gated operation.
    Error,
    /// `panic!` inside the gated operation (exercises `catch_unwind`
    /// isolation and poison recovery).
    Panic,
    /// Sleep this many milliseconds, then proceed normally (exercises
    /// deadlines and watchdogs).
    Delay(u64),
}

#[derive(Clone, Debug, PartialEq, Eq)]
struct Clause {
    kind: FaultKind,
    /// Fires on `num` out of every `den` hits (hash-selected, not
    /// periodic): `fnv(seed, site, hit) % den < num`.
    num: u64,
    den: u64,
}

/// A seeded, deterministic set of armed fault sites. Built
/// programmatically ([`FaultPlan::new`] + [`FaultPlan::arm`]) or parsed
/// from the `DEPYF_FAULTS` spec grammar ([`FaultPlan::parse`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    seed: u64,
    clauses: [Option<Clause>; 9],
}

impl FaultPlan {
    /// An empty plan (no armed sites) with the given seed.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, clauses: Default::default() }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Arm a site to fire on every hit.
    pub fn arm(self, site: Site, kind: FaultKind) -> FaultPlan {
        self.arm_rate(site, kind, 1, 1)
    }

    /// Arm a site to fire on `num` out of every `den` hits.
    pub fn arm_rate(mut self, site: Site, kind: FaultKind, num: u64, den: u64) -> FaultPlan {
        self.clauses[site.index()] = Some(Clause { kind, num, den: den.max(1) });
        self
    }

    /// Parse the `DEPYF_FAULTS` spec grammar (see module docs).
    pub fn parse(spec: &str) -> Result<FaultPlan, DepyfError> {
        let bad = |what: &str, part: &str| {
            DepyfError::Fault(format!("bad fault spec: {} '{}' (grammar: seed=<u64>;<site>=<error|panic|delay:<ms>>[@<num>/<den>];...)", what, part))
        };
        let mut plan = FaultPlan::new(0);
        for clause in spec.split(';').map(str::trim).filter(|s| !s.is_empty()) {
            let (key, value) = clause.split_once('=').ok_or_else(|| bad("clause", clause))?;
            let (key, value) = (key.trim(), value.trim());
            if key == "seed" {
                plan.seed = value.parse().map_err(|_| bad("seed", value))?;
                continue;
            }
            let site = Site::parse(key).ok_or_else(|| bad("site", key))?;
            let (kind_part, rate_part) = match value.split_once('@') {
                Some((k, r)) => (k.trim(), Some(r.trim())),
                None => (value, None),
            };
            let kind = match kind_part.split_once(':') {
                None => match kind_part {
                    "error" => FaultKind::Error,
                    "panic" => FaultKind::Panic,
                    _ => return Err(bad("kind", kind_part)),
                },
                Some(("delay", ms)) => FaultKind::Delay(ms.trim().parse().map_err(|_| bad("delay", ms))?),
                Some(_) => return Err(bad("kind", kind_part)),
            };
            let (num, den) = match rate_part {
                None => (1, 1),
                Some(r) => {
                    let (n, d) = r.split_once('/').ok_or_else(|| bad("rate", r))?;
                    let n: u64 = n.trim().parse().map_err(|_| bad("rate", r))?;
                    let d: u64 = d.trim().parse().map_err(|_| bad("rate", r))?;
                    if d == 0 {
                        return Err(bad("rate", r));
                    }
                    (n, d)
                }
            };
            plan.clauses[site.index()] = Some(Clause { kind, num, den });
        }
        Ok(plan)
    }

    fn is_empty(&self) -> bool {
        self.clauses.iter().all(Option::is_none)
    }
}

/// Per-site hit/fire counters of an installed plan.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SiteStats {
    /// Times the gate was reached while this site was armed.
    pub hits: u64,
    /// Times a fault actually fired (error returned, panic raised or
    /// delay slept).
    pub fired: u64,
}

/// An installed plan plus its counters. Counters start at zero on every
/// [`install`], so per-round chaos accounting needs no manual reset.
struct ActivePlan {
    plan: FaultPlan,
    hits: [AtomicU64; 9],
    fired: [AtomicU64; 9],
}

impl ActivePlan {
    fn new(plan: FaultPlan) -> ActivePlan {
        ActivePlan { plan, hits: Default::default(), fired: Default::default() }
    }

    /// Deterministic: whether hit `n` at `site` fires under this plan.
    fn fires(&self, site: Site, n: u64, clause: &Clause) -> bool {
        let h = crate::fnv::hash_str(&format!("{}:{}:{}", self.plan.seed, site.as_str(), n));
        h % clause.den < clause.num
    }

    fn check(&self, site: Site) -> Result<(), DepyfError> {
        let i = site.index();
        let Some(clause) = &self.plan.clauses[i] else { return Ok(()) };
        let n = self.hits[i].fetch_add(1, Ordering::Relaxed);
        if !self.fires(site, n, clause) {
            return Ok(());
        }
        self.fired[i].fetch_add(1, Ordering::Relaxed);
        match clause.kind {
            FaultKind::Delay(ms) => {
                std::thread::sleep(Duration::from_millis(ms));
                Ok(())
            }
            FaultKind::Error => {
                Err(DepyfError::Fault(format!("injected fault at {} (hit #{})", site.as_str(), n)))
            }
            FaultKind::Panic => panic!("injected panic at {} (hit #{})", site.as_str(), n),
        }
    }

    fn stats(&self, site: Site) -> SiteStats {
        let i = site.index();
        SiteStats {
            hits: self.hits[i].load(Ordering::Relaxed),
            fired: self.fired[i].load(Ordering::Relaxed),
        }
    }
}

/// 0 = uninitialized (env not consulted yet), 1 = off, 2 = a plan is
/// installed. The off path is a single relaxed load.
static MODE: AtomicU8 = AtomicU8::new(0);
static PLAN: RwLock<Option<Arc<ActivePlan>>> = RwLock::new(None);

fn current_plan() -> Option<Arc<ActivePlan>> {
    PLAN.read().unwrap_or_else(PoisonError::into_inner).clone()
}

/// Consult `DEPYF_FAULTS` exactly once, on the first gate of a process
/// that never called [`install`]. Malformed specs are reported and
/// ignored rather than crashing the workload.
fn init_from_env() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        match std::env::var("DEPYF_FAULTS") {
            Ok(spec) if !spec.trim().is_empty() => match FaultPlan::parse(&spec) {
                Ok(plan) if !plan.is_empty() => {
                    *PLAN.write().unwrap_or_else(PoisonError::into_inner) =
                        Some(Arc::new(ActivePlan::new(plan)));
                    MODE.store(2, Ordering::Relaxed);
                }
                Ok(_) => MODE.store(1, Ordering::Relaxed),
                Err(e) => {
                    eprintln!("[depyf] ignoring malformed DEPYF_FAULTS: {}", e);
                    MODE.store(1, Ordering::Relaxed);
                }
            },
            _ => MODE.store(1, Ordering::Relaxed),
        }
    });
}

/// Install a plan process-wide, replacing any env-configured one, and
/// reset all counters. The returned guard clears the plan on drop —
/// hold it for the duration of a chaos round.
#[must_use = "dropping the guard immediately uninstalls the plan"]
pub fn install(plan: FaultPlan) -> FaultGuard {
    *PLAN.write().unwrap_or_else(PoisonError::into_inner) = Some(Arc::new(ActivePlan::new(plan)));
    MODE.store(2, Ordering::Relaxed);
    FaultGuard { _priv: () }
}

/// RAII handle from [`install`]: dropping it clears the active plan.
pub struct FaultGuard {
    _priv: (),
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        *PLAN.write().unwrap_or_else(PoisonError::into_inner) = None;
        MODE.store(1, Ordering::Relaxed);
    }
}

/// The injection gate, called at each named site. Unconfigured: one
/// relaxed atomic load, then `Ok`. Configured: counts the hit and
/// either proceeds, sleeps (delay), returns [`DepyfError::Fault`]
/// (error) or panics (panic).
#[inline]
pub fn gate(site: Site) -> Result<(), DepyfError> {
    loop {
        match MODE.load(Ordering::Relaxed) {
            1 => return Ok(()),
            0 => init_from_env(),
            _ => {
                let Some(active) = current_plan() else { return Ok(()) };
                return active.check(site);
            }
        }
    }
}

/// Counters of the currently installed plan (zeros when none is
/// installed). Chaos rounds reconcile these against the resilience
/// counters the injected faults produced.
pub fn stats(site: Site) -> SiteStats {
    match current_plan() {
        Some(active) => active.stats(site),
        None => SiteStats::default(),
    }
}

/// Total faults fired across all sites of the current plan.
pub fn fired_total() -> u64 {
    match current_plan() {
        Some(active) => SITES.iter().map(|&s| active.stats(s).fired).sum(),
        None => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn site_names_round_trip() {
        for site in SITES {
            assert_eq!(Site::parse(site.as_str()), Some(site), "{}", site);
        }
        assert_eq!(Site::parse("nope"), None);
    }

    #[test]
    fn spec_grammar_parses_and_rejects() {
        let plan =
            FaultPlan::parse("seed=7;backend.plan=error@1/5;module.call=panic;pipeline.stage=delay:20@1/3")
                .unwrap();
        assert_eq!(plan.seed(), 7);
        assert_eq!(
            plan.clauses[Site::BackendPlan.index()],
            Some(Clause { kind: FaultKind::Error, num: 1, den: 5 })
        );
        assert_eq!(
            plan.clauses[Site::ModuleCall.index()],
            Some(Clause { kind: FaultKind::Panic, num: 1, den: 1 })
        );
        assert_eq!(
            plan.clauses[Site::PipelineStage.index()],
            Some(Clause { kind: FaultKind::Delay(20), num: 1, den: 3 })
        );
        assert!(plan.clauses[Site::DiskCacheRead.index()].is_none());

        // The supervision sites joined the grammar in PR 10.
        let sup = FaultPlan::parse("seed=3;worker.heartbeat=delay:500@1/3;serve.admission=error@1/2").unwrap();
        assert_eq!(
            sup.clauses[Site::WorkerHeartbeat.index()],
            Some(Clause { kind: FaultKind::Delay(500), num: 1, den: 3 })
        );
        assert_eq!(
            sup.clauses[Site::ServeAdmission.index()],
            Some(Clause { kind: FaultKind::Error, num: 1, den: 2 })
        );

        // Whitespace tolerated; same plan.
        let spaced = FaultPlan::parse(
            " seed = 7 ; backend.plan = error @ 1/5 ; module.call = panic ; pipeline.stage = delay: 20 @ 1/3 ",
        );
        // `seed = 7` has spaces inside key/value which we trim; the rate
        // split also trims. Only the delay param keeps a space → trimmed.
        assert_eq!(spaced.unwrap(), plan);

        for bad in [
            "backend.plan",            // no '='
            "nosuch.site=error",       // unknown site
            "module.call=explode",     // unknown kind
            "module.call=delay",       // delay without ms
            "module.call=delay:abc",   // bad ms
            "module.call=error@1",     // rate without '/'
            "module.call=error@1/0",   // zero denominator
            "seed=banana",             // bad seed
        ] {
            let err = FaultPlan::parse(bad).expect_err(bad);
            assert_eq!(err.layer(), "fault", "{}", bad);
        }
    }

    #[test]
    fn firing_is_deterministic_and_rate_bounded() {
        let plan = FaultPlan::new(42).arm_rate(Site::BackendPlan, FaultKind::Error, 1, 4);
        let a = ActivePlan::new(plan.clone());
        let b = ActivePlan::new(plan);
        let mut fired_a = 0u64;
        for _ in 0..400 {
            let ra = a.check(Site::BackendPlan);
            let rb = b.check(Site::BackendPlan);
            assert_eq!(ra.is_err(), rb.is_err(), "same seed, same hit → same outcome");
            if ra.is_err() {
                fired_a += 1;
            }
        }
        let st = a.stats(Site::BackendPlan);
        assert_eq!(st.hits, 400);
        assert_eq!(st.fired, fired_a);
        // Hash selection at 1/4 over 400 hits lands well inside (0, 400).
        assert!(st.fired > 25 && st.fired < 175, "fired {} of 400", st.fired);
        // A different seed fires a different subset.
        let c = ActivePlan::new(FaultPlan::new(43).arm_rate(Site::BackendPlan, FaultKind::Error, 1, 4));
        let mut diverged = false;
        for n in 0..400u64 {
            let clause = Clause { kind: FaultKind::Error, num: 1, den: 4 };
            if a.fires(Site::BackendPlan, n, &clause) != c.fires(Site::BackendPlan, n, &clause) {
                diverged = true;
                break;
            }
        }
        assert!(diverged, "seeds 42 and 43 select identical fault subsets");
    }

    #[test]
    fn unarmed_sites_never_fire_and_count_nothing() {
        let a = ActivePlan::new(FaultPlan::new(1).arm(Site::ModuleCall, FaultKind::Error));
        for _ in 0..10 {
            a.check(Site::BackendPlan).unwrap();
        }
        assert_eq!(a.stats(Site::BackendPlan), SiteStats::default());
        assert!(a.check(Site::ModuleCall).is_err(), "1/1 rate fires every hit");
        assert_eq!(a.stats(Site::ModuleCall), SiteStats { hits: 1, fired: 1 });
    }

    #[test]
    fn full_rate_error_message_names_site_and_hit() {
        let a = ActivePlan::new(FaultPlan::new(9).arm(Site::DiskCacheWrite, FaultKind::Error));
        let err = a.check(Site::DiskCacheWrite).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("injected fault at disk_cache.write"), "{}", msg);
        assert!(err.is_transient(), "injected faults retry");
    }

    /// Global install/uninstall round-trip with an *empty* plan — safe to
    /// run concurrently with every other unit test in the binary because
    /// no site is armed (gates stay Ok). Armed-plan behavior is covered
    /// above without touching the global, and end-to-end in tests/chaos.rs
    /// (which serializes on its own lock).
    #[test]
    fn install_guard_round_trips_without_arming() {
        {
            let _guard = install(FaultPlan::new(5));
            assert_eq!(MODE.load(Ordering::Relaxed), 2);
            gate(Site::ModuleCall).unwrap();
            gate(Site::BackendPlan).unwrap();
            assert_eq!(stats(Site::ModuleCall), SiteStats::default(), "empty plan arms nothing");
            assert_eq!(fired_total(), 0);
        }
        assert_eq!(MODE.load(Ordering::Relaxed), 1);
        assert!(current_plan().is_none(), "guard drop clears the plan");
        gate(Site::ModuleCall).unwrap();
    }
}
