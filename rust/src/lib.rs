//! # depyf-rs
//!
//! A Rust reproduction of **depyf** ("Open the Opaque Box of PyTorch
//! Compiler for Machine Learning Researchers", You et al., 2024), built as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! ## The public API: [`api`]
//!
//! Everything user-facing funnels through [`api`] — a fluent session
//! builder, pluggable backends, typed artifacts, and one structured error
//! type ([`DepyfError`]):
//!
//! ```no_run
//! use depyf::prelude::*;
//!
//! # fn main() -> Result<(), DepyfError> {
//! // with depyf.prepare_debug(dir): run under the compiler, dump everything.
//! let mut session = Session::builder()
//!     .dump_to("dump_dir")
//!     .backend_named("eager")          // or .backend(Rc::new(MyBackend))
//!     .isa(IsaVersion::V311)
//!     .build()?;
//! session.run_source("main", "print((torch.ones([2]) * 2).sum().item())\n")?;
//! let artifacts = session.finish()?;   // typed Artifacts + manifest.json
//! for a in &artifacts {
//!     println!("[{}] {}", a.kind, a.path.display());
//! }
//!
//! // with depyf.debug(): step through compiled-graph dump lines.
//! let dbg = Session::builder().dump_to("dbg_dir").trace(TraceMode::StepGraphs).build()?;
//! dbg.debugger.break_at("__compiled_fn_1.py", 2);
//! # Ok(()) }
//! ```
//!
//! Custom graph compilers plug in exactly like `torch.compile(backend=...)`:
//! implement [`api::Backend`], call [`api::register_backend`], and pass the
//! name to `backend_named` (see `examples/custom_backend.rs`). Backend
//! failures follow an explicit [`api::FallbackPolicy`] instead of silently
//! degrading. The pre-builder entry points ([`session::DebugSession`],
//! [`backend::compile_graph`]) remain as deprecated shims.
//!
//! ## The stack underneath
//!
//! * **Layer 3 (this crate)** — the compiler being opened *and* the tool
//!   that opens it: a Python-subset language & VM ([`pylang`], [`vm`],
//!   [`bytecode`]), a Dynamo-like graph-capturing frontend ([`dynamo`]),
//!   the symbolic-execution bytecode decompiler ([`decompiler`]), the
//!   introspection/debugging machinery ([`api`], [`hijack`], [`debugger`]),
//!   and graph backends ([`backend`]) including an XLA/PJRT backend.
//! * **Layer 2 (build-time JAX)** — a transformer model AOT-lowered to HLO
//!   text artifacts loaded by [`runtime`].
//! * **Layer 1 (build-time Pallas)** — fused attention / layernorm kernels
//!   called from Layer 2.
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md` for
//! the paper-vs-measured results.

pub mod api;
pub mod backend;
pub mod bytecode;
pub mod corpus;
pub mod debugger;
pub mod decompiler;
pub mod dynamo;
pub mod graph;
pub mod hijack;
pub mod metrics;
pub mod pylang;
pub mod runtime;
pub mod session;
pub mod tensor;
pub mod value;
pub mod vm;

pub use api::DepyfError;

/// Convenient re-exports for examples and tests.
pub mod prelude {
    pub use crate::api::{
        lookup_backend, register_backend, Artifact, ArtifactKind, Backend, CompileCtx, DepyfError,
        EagerBackend, FallbackPolicy, Session, SessionBuilder, TraceMode, XlaBackend,
    };
    pub use crate::backend::BackendKind;
    #[allow(deprecated)]
    pub use crate::session::DebugSession;
    pub use crate::bytecode::{disassemble, CodeObject, Instr, IsaVersion};
    pub use crate::decompiler::{decompile, Decompiler};
    pub use crate::dynamo::{Dynamo, DynamoConfig};
    pub use crate::pylang::compile_module;
    pub use crate::runtime::Runtime;
    pub use crate::tensor::Tensor;
    pub use crate::value::Value;
    pub use crate::vm::Vm;
}
