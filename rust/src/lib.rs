//! # depyf-rs
//!
//! A Rust reproduction of **depyf** ("Open the Opaque Box of PyTorch
//! Compiler for Machine Learning Researchers", You et al., 2024), built as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the compiler being opened *and* the tool
//!   that opens it: a Python-subset language & VM ([`pylang`], [`vm`],
//!   [`bytecode`]), a Dynamo-like graph-capturing frontend ([`dynamo`]),
//!   the symbolic-execution bytecode decompiler ([`decompiler`]), the
//!   introspection/debugging API ([`session`], [`hijack`], [`debugger`]),
//!   and graph backends ([`backend`]) including an XLA/PJRT backend.
//! * **Layer 2 (build-time JAX)** — a transformer model AOT-lowered to HLO
//!   text artifacts loaded by [`runtime`].
//! * **Layer 1 (build-time Pallas)** — fused attention / layernorm kernels
//!   called from Layer 2.
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md` for
//! the paper-vs-measured results.

pub mod backend;
pub mod bytecode;
pub mod corpus;
pub mod debugger;
pub mod decompiler;
pub mod dynamo;
pub mod graph;
pub mod hijack;
pub mod metrics;
pub mod pylang;
pub mod runtime;
pub mod session;
pub mod tensor;
pub mod value;
pub mod vm;

/// Convenient re-exports for examples and tests.
pub mod prelude {
    pub use crate::backend::BackendKind;
    pub use crate::bytecode::{disassemble, CodeObject, Instr, IsaVersion};
    pub use crate::decompiler::{decompile, Decompiler};
    pub use crate::dynamo::{Dynamo, DynamoConfig};
    pub use crate::pylang::compile_module;
    pub use crate::runtime::Runtime;
    pub use crate::session::DebugSession;
    pub use crate::tensor::Tensor;
    pub use crate::value::Value;
    pub use crate::vm::Vm;
}
