//! # depyf-rs
//!
//! A Rust reproduction of **depyf** ("Open the Opaque Box of PyTorch
//! Compiler for Machine Learning Researchers", You et al., 2024), built as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! ## The public API: [`api`]
//!
//! Everything user-facing funnels through [`api`] — a fluent session
//! builder, pluggable backends, typed artifacts, and one structured error
//! type ([`DepyfError`]):
//!
//! ```no_run
//! use depyf::prelude::*;
//!
//! # fn main() -> Result<(), DepyfError> {
//! // with depyf.prepare_debug(dir): run under the compiler, dump everything.
//! let mut session = Session::builder()
//!     .dump_to("dump_dir")
//!     .backend_named("eager")          // or .backend(Arc::new(MyBackend))
//!     .isa(IsaVersion::V311)
//!     .build()?;
//! session.run_source("main", "print((torch.ones([2]) * 2).sum().item())\n")?;
//! let artifacts = session.finish()?;   // typed Artifacts + manifest.json
//! for a in &artifacts {
//!     println!("[{}] {}", a.kind, a.path.display());
//! }
//!
//! // with depyf.debug(): step through compiled-graph dump lines.
//! let dbg = Session::builder().dump_to("dbg_dir").trace(TraceMode::StepGraphs).build()?;
//! dbg.debugger.break_at("__compiled_fn_1.py", 2);
//! # Ok(()) }
//! ```
//!
//! ## The backend pipeline
//!
//! Graph compilation is a staged, inspectable pipeline rather than a
//! one-shot callback. A typed [`api::CompileRequest`] (graph, example
//! input specs, guard context, content-hash cache key, verbosity) flows
//! through two explicit stages:
//!
//! * [`api::Backend::plan`] returns a declarative [`api::CompilePlan`] —
//!   partitions (node sets, per-partition target, per-partition cache
//!   key) and padding/bucketing decisions — that dumps to
//!   `__plan_*.json` and round-trips through [`api::CompilePlan::parse`].
//! * [`api::Backend::lower`] realizes the plan as an
//!   [`api::CompiledModule`]: `call()` executes, `artifacts()` exposes
//!   per-partition HLO/plan dumps (indexed in `manifest.json`), and
//!   `stats()` feeds the `metrics.json` `"modules"` array.
//!
//! Every backend declares an [`api::Capabilities`] bitset (`PARTITION`,
//! `DYNAMIC_BATCH`, `ASYNC`, `WRAPPER`, runtime needs) so the registry,
//! [`api::SessionBuilder`] (`.require(caps)`) and the CLI validate
//! configurations before anything compiles. Five backends ship in-tree:
//!
//! * `eager` — node-by-node CPU reference execution ([`backend::eager`]).
//! * `xla` — one PJRT executable per captured graph ([`backend::xla`]).
//! * `sharded` — splits large graphs at articulation points into several
//!   PJRT/eager executables and stitches outputs ([`backend::sharded`]);
//!   partition boundaries are typed artifacts.
//! * `batched` — pads/buckets the dynamic leading dim so one executable
//!   serves every guard entry in the same bucket ([`backend::batched`]),
//!   reusing the content-hash compile cache per bucket.
//! * `recording` — a *wrapper* backend ([`backend::recording`]) that
//!   decorates any inner backend's modules and serializes every call into
//!   a self-contained, versioned `__trace_*.json` bundle
//!   ([`api::trace::TraceBundle`], `ArtifactKind::Trace` in the
//!   manifest); `recording:<name>` on the CLI wraps any registered
//!   backend.
//!
//! Custom graph compilers plug in exactly like `torch.compile(backend=...)`:
//! implement [`api::Backend`], call [`api::register_backend`], and pass the
//! name to `backend_named` (see `examples/custom_backend.rs`). Backend
//! failures follow an explicit [`api::FallbackPolicy`] instead of silently
//! degrading. The pre-builder entry points (`DebugSession::prepare_debug`,
//! `backend::compile_graph`, `hijack::graph_line_table`) are removed; use
//! the builder and the pipeline above.
//!
//! ## Graph optimizer
//!
//! Between capture and lowering sits a real compiler optimizer
//! ([`graph::opt`]), run at `Backend::plan` time for **every** backend at
//! the request's `--opt-level` (default 2):
//!
//! | level | passes |
//! |-------|--------|
//! | `0`   | none — capture verbatim, no elementwise fusion |
//! | `1`   | `const_fold` → `cse` → `dce` |
//! | `2`   | `const_fold` → `algebraic` → `cse` → `dce`, plus fused elementwise chains in the eager [`backend::eager::ExecPlan`] |
//!
//! `const_fold` evaluates all-const op nodes with the eager executor's
//! own `eval_op` (folded bits are execution bits); `algebraic` applies
//! only **bit-exact** identities (`x*1`, `x/1`, `x-0`, double-neg,
//! `transpose∘transpose`, `reshape∘reshape`; `x+0`/`x*0` fire only when
//! a sign/finiteness analysis proves them exact — `-0.0 + 0.0` flips a
//! sign bit, `-1.0 * 0.0 = -0.0`); `cse` merges structurally identical
//! nodes by per-node hash; `dce` drops unreachable ops while keeping
//! every placeholder (the call convention). Optimization **never changes
//! results**: the conformance suite replays the whole corpus at
//! `--opt-level 0` vs `2` and demands bitwise equality on
//! eager/sharded/batched.
//!
//! True to the paper, the transformation is dumped, not hidden:
//! `Session::finish()` writes `__optimized_*.txt` (a commented pass table
//! plus the optimized graph printed exactly like `__compiled_fn_*.py` —
//! diff the two files to see what the optimizer did) and
//! `__optimized_*.json` (lossless serde graph + pass stats,
//! `ArtifactKind::OptimizedGraph` in the manifest); `__plan_*.json`
//! records the level and per-pass node deltas (`"opt"`), and
//! `metrics.json`'s `"modules"` entries carry the same deltas.
//!
//! **Fusion lives below the IR**: there is no `FusedElementwise` op kind.
//! The eager `ExecPlan` groups broadcasting-compatible elementwise runs
//! into regions executed as one stride-walked pass (chunked, zero
//! intermediate tensors); XLA lowers the folded-but-unfused graph and
//! lets PJRT fuse; trace bundles always serialize the *pre-optimizer*
//! captured graph, so `depyf replay --opt-level 0` vs `2` bisects any
//! optimizer suspicion (see `rust/tests/README.md`). Compile caches key
//! on the **optimized** graph's `content_hash`, so graphs that become
//! equivalent after optimization share executables.
//!
//! ## Performance
//!
//! The request path — the paper's "guards are checked on every hooked
//! call" loop — is engineered, not incidental:
//!
//! * **Guard dispatch** ([`dynamo::GuardTable`]): each hooked code object
//!   precompiles its cached entries into a two-stage dispatcher. Stage 1
//!   buckets entries by a cheap discriminant (rank of the first-argument
//!   tensor) merged with a wildcard list in insertion order, so dispatch
//!   picks exactly the entry a linear scan would. Stage 2 checks compiled
//!   guards against a memoized resolved-slot vector: every distinct
//!   [`dynamo::Origin`] is resolved **at most once per call**, identity
//!   guards compare pre-computed `(tag, address)` tokens, and constant
//!   guards reject on a pre-computed FNV fingerprint before any
//!   structural comparison. Cache-hit logging sits behind
//!   [`dynamo::Verbosity`]: at the default level no format string is
//!   built on the hit path. At `cache_limit` the table **evicts its
//!   least-recently-used entry** (per-entry hit counter + recency stamp)
//!   and compiles the new specialization — nothing runs uncompiled, hot
//!   entries survive churn, and evictions are counted in `metrics.json`.
//! * **Eager executor** ([`backend::eager::ExecPlan`]): graph compilation
//!   produces a per-graph plan — constants pre-materialized, op steps in
//!   topological order, buffer liveness (dead slots freed eagerly), and a
//!   reusable env arena — so steady-state calls do no planning work.
//!   Elementwise broadcasting precomputes one stride vector per operand
//!   ([`tensor::Tensor::broadcast_strides`]) and walks the output with an
//!   odometer instead of a per-element div/mod chain; same-shape and
//!   1-element operands take linear fast paths; matmul switches to a
//!   k-blocked kernel when the B panel outgrows cache (bitwise-identical
//!   results — accumulation order is unchanged).
//! * **Compile cache** ([`graph::Graph::content_hash`], [`runtime`]):
//!   PJRT executables are cached under `graph:{content_hash}` — a stable
//!   structural hash (shapes + op kinds + constants, name excluded) — so
//!   identical graphs compile once per process however many sessions
//!   capture them. [`runtime::Runtime::shared`] is the process-wide
//!   handle the CLI uses, and its [`runtime::DiskCache`] persists an
//!   HLO→artifact index (`$DEPYF_CACHE_DIR`, default `.depyf_cache`) so
//!   repeated runs skip graph lowering entirely.
//!
//! Per-session counters land in the `metrics.json` dump artifact
//! (cache hits/misses, guard checks/failures, `compile_ns`). The bench
//! suite (`cargo bench --bench guard_dispatch`, plus the other benches)
//! merges machine-readable numbers into `BENCH_hotpath.json`:
//! `{"entries": [{"bench", "name", "value", "unit"}, ...]}` — guard-hit
//! latency, eager MLP step and compile-cache hit vs miss live there; CI
//! smoke-runs the suite with `DEPYF_BENCH_QUICK=1`.
//!
//! ## Codegen backend
//!
//! `--backend codegen` ([`codegen`]) is the step past the interpreted
//! `ExecPlan`: `Backend::lower` **compiles** the optimized graph into a
//! flat [`codegen::LoopProgram`] — a linear instruction buffer over a
//! slot-numbered value arena — and steady-state `call()`s just execute
//! that buffer. Three things distinguish it from interpretation:
//!
//! * **Register allocation**: liveness analysis assigns every value a
//!   numbered slot and reuses slots the moment their last reader has run
//!   (the dump prints `peak live` vs total slot count); freed buffers are
//!   recycled through a small free-list instead of reallocated.
//! * **Loop specialization at lower time**: each fused elementwise region
//!   becomes one `loop` instruction whose operand *stride classes*
//!   (`dense` / `splat` / `row(period=k)` / `strided[..]`) are resolved
//!   when the program is built — the common contiguous case runs a
//!   straight-line chunk loop with no per-element odometer. Matmuls lower
//!   to a k-blocked kernel with **fused epilogues** (bias-add /
//!   activation applied to the output tile in-cache), and large panels
//!   row-tile across a [`serve`] worker pool (`CodegenBackend::with_threads`)
//!   in a per-element-order-preserving way, so threading is bitwise-safe.
//! * **Transparency**: the whole program dumps as a readable
//!   `__loopir_*.txt` artifact (`ArtifactKind::LoopIr`, indexed in
//!   `manifest.json`). Each line is one instruction —
//!   `i1   loop   s2 = [3, 4] <12 elems, 5 ops>` followed by its inputs'
//!   stride classes and scalar steps, `i2   matmul s3 = s0 @ s1 [m=.. k=.. n=..]
//!   path=blocked` plus its `epilogue:` steps, `eval` for the op kinds that
//!   fall back to the reference executor — with `free [sN]` annotations
//!   showing where slots die. Diff it against `__optimized_*.txt` to see
//!   exactly what compilation did.
//!
//! Results are bitwise-equal to eager by construction (same scalar bodies,
//! same accumulation order) and by evidence: the conformance sweep holds
//! `codegen` to the oracle at `eps = 0` across the corpus at opt levels
//! 0 and 2, and `depyf replay --backend codegen --against eager` bisects
//! any suspicion. `benches/codegen.rs` gates the speedup that justifies
//! the subsystem (≥1.5x on elementwise chains, ≥1.3x on matmul+epilogue
//! vs the interpreted plan) into `BENCH_codegen.json`.
//!
//! ## Concurrent serving
//!
//! The serving story — compile once, dispatch from many threads — is a
//! first-class subsystem ([`serve`]), and the thread-safety contract it
//! rests on is explicit, layer by layer:
//!
//! * **Backend registry** ([`api::register_backend`]): a process-wide
//!   `RwLock` map. Lookups take the read lock; registration from any
//!   thread is visible to all. [`api::Backend`] is `Send + Sync`.
//! * **Compiled modules**: [`api::CompiledModule`] is `Send + Sync` and
//!   dispatched through `Arc` handles — one compile, any number of
//!   calling threads. Inputs stay call-local `Rc<Tensor>`s; tensors
//!   themselves share data via `Arc` and cross threads freely.
//! * **Compile caches**: the serve layer's [`serve::ModuleCache`] (graph
//!   content hash → module) takes snapshot reads on the dispatch path and
//!   compiles *outside* the lock — a compile in flight never blocks a
//!   cache hit. The on-disk HLO index ([`runtime::DiskCache`]) publishes
//!   updates by atomic rename, so concurrent writers (even separate
//!   processes) can lose at most a cold cache line, never corrupt it.
//! * **Sessions stay single-threaded**: [`dynamo::Dynamo`], the VM and
//!   the [`dynamo::GuardTable`] are session-local (`Rc`-based values).
//!   Guard usage counters (hits, recency) are atomics so the LRU story
//!   holds under shared-reference readers; concurrency across sessions
//!   comes from each serving thread owning its own session while sharing
//!   the registry, module cache and backends.
//! * **The PJRT runtime is thread-confined**: [`runtime::Runtime`] wraps
//!   its client and executables in `ThreadBound` — using them off the
//!   owning thread is a clean error, not UB. `depyf serve` therefore
//!   drives CPU backends (`xla` is rejected up front).
//!
//! `Capabilities::ASYNC` is real: the `async` wrapper backend
//! ([`serve::AsyncBackend`], `async:<name>` on the CLI) lowers modules
//! whose `submit()` returns a [`serve::CallFuture`] backed by a small
//! worker pool — hold several futures to overlap calls — while plain
//! `call()` keeps the synchronous contract (submit + wait). The
//! `pipelined` backend ([`serve::PipelinedShardedBackend`]) runs the
//! sharded partition chain with one stage thread per shard, so shard k of
//! call i overlaps shard k+1 of call i−1.
//!
//! `depyf serve --threads N --backend <name>` drives N concurrent
//! sessions over the table1 model corpus, checks every output against a
//! single-thread reference run, merges per-thread metrics into
//! `metrics.json` and writes throughput/latency percentiles (1-thread
//! baseline vs N-thread, with the speedup) to `BENCH_serve.json`;
//! `benches/serve.rs` sweeps thread counts.
//!
//! ## Supervised serving
//!
//! An `async:` backend's worker fleet does not merely exist — it is
//! *supervised* ([`serve::Supervisor`]), and the serving stack carries
//! per-request deadlines end to end:
//!
//! * **Heartbeats + watchdog**: every supervised worker stamps an atomic
//!   heartbeat when it picks up a job. A watchdog scans the fleet; a busy
//!   worker silent past the stall budget (`--stall-ms`, default 1000) is
//!   declared lost, its in-flight call is resolved out from under it with
//!   a typed transient error (first write wins, so the caller degrades to
//!   the eager fallback instead of hanging), and a replacement is spawned
//!   under a restart budget with doubling backoff. Past the budget the
//!   supervisor gives up: queued jobs flush with a typed error and new
//!   work is rejected, so a crash-looping fleet fails fast.
//! * **Admission control** ([`serve::AdmissionPolicy`], `--admission`):
//!   the supervisor queue is bounded (`--queue-cap`, default 64). On
//!   overflow, `block` applies backpressure, `shed` rejects with
//!   [`DepyfError::Overloaded`] (deliberately *not* transient — the
//!   dispatch path maps it straight to the bitwise-correct eager
//!   fallback), and `deadline-aware` additionally sheds any job whose
//!   remaining deadline cannot cover the observed p50 service time.
//! * **Deadline propagation** ([`serve::Deadline`],
//!   [`serve::with_deadline`]): `--deadline-ms` no longer just bounds the
//!   caller's wait — the deadline is published to the dispatch path and
//!   rides into every layer that could waste work: supervised jobs abort
//!   at dequeue when their budget is spent, every `pipelined` stage
//!   checks the packet's deadline before computing, and the module
//!   cache's compile path refuses to start lowering for an
//!   already-expired request. Each early abort counts into
//!   `deadline_propagated_aborts`.
//! * **Graceful drain**: `depyf serve` stops admitting, lets in-flight
//!   work finish, waits for the fleet to be restored (every watchdog kill
//!   matched by a respawn), then merges supervisor counters — `sheds`,
//!   `respawns`, `watchdog_kills`, `queue_depth_p99` — into
//!   `metrics.json`, the serve summary and `BENCH_serve.json`
//!   deterministically.
//!
//! ## Fault tolerance
//!
//! Wrapping a workload in depyf must never make it *less* reliable than
//! running without it, so the dispatch path degrades instead of dying:
//!
//! * **Panic isolation**: backend `plan`/`lower` and every
//!   `CompiledModule::call` run under `catch_unwind`; a panic becomes
//!   [`DepyfError::Panic`] (`api::DepyfError::layer() == "panic"`) and
//!   flows through the normal [`api::FallbackPolicy`]. Every
//!   process-wide lock (backend registry, executable caches,
//!   [`runtime::DiskCache`], [`serve::ModuleCache`], the worker pool)
//!   recovers from poison instead of unwrapping, so one panicked thread
//!   cannot brick the others.
//! * **Retry + circuit breaker** ([`backend::ResilientBackend`],
//!   `resilient:<name>` on the CLI, applied automatically by
//!   `depyf serve`): transient compile failures
//!   ([`DepyfError::is_transient`]) are retried with backoff; after 3
//!   consecutive failures the breaker trips **open** and compiles fail
//!   fast (degrading dispatch to eager under `FallbackPolicy::Eager`);
//!   after a cooldown one **half-open** probe is let through — success
//!   closes the breaker, failure reopens it.
//! * **Call-time degradation**: a compiled module whose call fails
//!   transiently is retried once, then served by a lazily-built eager
//!   fallback module (bitwise-equal to the reference executor); trace
//!   bundles record which backend actually served each call
//!   (`served_by`), and `depyf replay --backend recorded` re-runs the
//!   trace on the originally requested backend to confirm the fallback
//!   was output-equivalent.
//! * **Deadlines**: [`serve::CallFuture::wait_timeout`] never blocks past
//!   its deadline, and `depyf serve --deadline-ms <n>` abandons stuck
//!   calls (the abandoned worker finishes harmlessly thanks to drop-safe
//!   promises) and serves the eager fallback instead.
//! * **Cache integrity**: disk-cache index entries carry an FNV checksum
//!   of the cached HLO; corruption quarantines the entry
//!   (`<file>.quarantined`) and recompiles rather than erroring.
//!
//! All of it is *testable on demand* via deterministic fault injection
//! ([`faults`]): `DEPYF_FAULTS="seed=7;backend.plan=error@1/5;`
//! `module.call=panic@1/7;pipeline.stage=delay:20@1/3"` arms seeded
//! faults (kinds `error` | `panic` | `delay:<ms>`, rate `@num/den`) at
//! the named sites `backend.plan`, `backend.lower`, `module.call`,
//! `disk_cache.read`, `disk_cache.write`, `worker_pool.submit`,
//! `pipeline.stage`, `worker.heartbeat` (a `delay` wedges a supervised
//! job past the stall budget, provoking the watchdog) and
//! `serve.admission` (forces a shed at the supervisor's front door).
//! Whether hit *n* at a site fires is a pure function
//! of `(seed, site, n)`, so any chaos failure reproduces from its seed
//! (see `rust/tests/README.md`). Unconfigured, each site costs one
//! relaxed atomic load. Retries, degradations, breaker trips/skips,
//! caught panics and timeouts all land in `metrics.json` and the
//! `depyf serve` summary, which also reports per-thread failures and
//! exits non-zero if any serving thread died.
//!
//! ## Testing & conformance
//!
//! Cross-backend correctness is evidence, not hope: the **eager executor
//! is the oracle**, and `tests/conformance.rs` is the harness that holds
//! every other backend to it (see `rust/tests/README.md` for the full
//! strategy).
//!
//! * **Record**: programs run under the `recording` wrapper, which
//!   captures each compiled fn's calls (bit-exact f32 payloads) plus the
//!   lossless graph serialization ([`graph::serde`], floats as raw bit
//!   patterns — `parse(render(g))` preserves `content_hash`) into a
//!   versioned [`api::trace::TraceBundle`].
//! * **Replay**: [`backend::replay_bundle`] recompiles a bundle's graph
//!   on any registered backend and re-executes the recorded inputs —
//!   against the recorded outputs, or against a fresh oracle run in
//!   differential mode (`depyf replay --against eager`). Comparison is
//!   bitwise at `eps = 0` (sharded/batched must match the oracle
//!   bit-for-bit) and eps-based for XLA's fused float math.
//! * **Localize**: on mismatch, the graph is cut into single-op
//!   partitions with the sharded partitioner and each op is replayed
//!   against oracle intermediates ([`backend::localize_divergence`]); the
//!   first diverging op yields a **minimized single-op repro bundle**.
//! * **Sweep**: the full table1 model corpus plus ≥200 deterministic
//!   generated graphs per backend (seeded generator in `tests/support`,
//!   shared with `tests/proptests.rs`; same seed → same graphs). CI runs
//!   the quick sweep (`DEPYF_CONFORMANCE_QUICK=1`) and uploads mismatch
//!   repro bundles as artifacts on failure.
//!
//! ## Fuzzing
//!
//! The conformance sweep holds backends to the oracle at the *graph*
//! level; [`fuzz`] (`depyf fuzz --seed N --iters M`) attacks the layers
//! above it with **program-level differential fuzzing**. A seeded
//! generator builds whole `pylang` programs from composable templates —
//! data-dependent branches, `for`/`while` loops with `break`/`continue`,
//! closures, container mutation, tensor-shape changes across guard
//! boundaries, mixed int/float/bool arithmetic — then applies
//! semantics-preserving mutations (noop wrapping, call duplication onto
//! the guard-cache hit path) and semantics-perturbing ones (shape/constant
//! perturbation, method swaps including deliberately unsupported ones).
//! Each program runs twice — plain VM vs dynamo-hooked — and the runs
//! must agree **bitwise**: same printed output, same result bit patterns
//! (`-0.0` and NaN payloads included), and on failure the *same* error.
//! The sweep crosses every registered graph backend (eager, sharded,
//! batched, codegen, wrapper compositions) with opt levels 0 and 2, so
//! one run also cross-checks the optimizer and the wrapper stack.
//! Divergences, disagreeing errors, and panics caught under
//! `catch_unwind` are auto-shrunk by program-level delta debugging,
//! chained into the replay single-op localizer, and emitted as committed
//! regression bundles (`tests/fuzz_regressions/`) that CI replays bitwise
//! on every backend. Everything derives from `(seed, iter)` — no wall
//! clock anywhere — so every finding reproduces from its coordinates.
//! `depyf fuzz --serve --threads T` turns the same corpus against the
//! concurrent dispatch path: T threads race each program through one
//! shared [`serve::ModuleCache`] per backend × opt level and every
//! thread's outcome is diffed against the single-thread reference
//! (bundles are tagged `serve:<inner>` and replayed concurrently by the
//! regression sweep); `--bisect-opt` re-runs each divergence at O0/O1/O2
//! and records the first exhibiting level in the bundle's
//! `first_divergent_opt` field, separating optimizer regressions from
//! capture bugs at triage time.
//!
//! ## The stack underneath
//!
//! * **Layer 3 (this crate)** — the compiler being opened *and* the tool
//!   that opens it: a Python-subset language & VM ([`pylang`], [`vm`],
//!   [`bytecode`]), a Dynamo-like graph-capturing frontend ([`dynamo`]),
//!   the symbolic-execution bytecode decompiler ([`decompiler`]), the
//!   introspection/debugging machinery ([`api`], [`hijack`], [`debugger`]),
//!   and graph backends ([`backend`]) including an XLA/PJRT backend.
//! * **Layer 2 (build-time JAX)** — a transformer model AOT-lowered to HLO
//!   text artifacts loaded by [`runtime`].
//! * **Layer 1 (build-time Pallas)** — fused attention / layernorm kernels
//!   called from Layer 2.
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md` for
//! the paper-vs-measured results.

pub mod api;
pub mod backend;
mod fnv;
pub mod bytecode;
pub mod codegen;
pub mod corpus;
pub mod debugger;
pub mod decompiler;
pub mod dynamo;
pub mod faults;
pub mod fuzz;
pub mod graph;
pub mod hijack;
pub mod metrics;
pub mod pylang;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod tensor;
pub mod value;
pub mod vm;

pub use api::DepyfError;

/// Convenient re-exports for examples and tests.
pub mod prelude {
    pub use crate::api::{
        lookup_backend, register_backend, Artifact, ArtifactKind, Backend, Capabilities,
        CompilePlan, CompileRequest, CompiledModule, DepyfError, EagerBackend, FallbackPolicy,
        OptLevel, Session, SessionBuilder, TraceMode, XlaBackend,
    };
    pub use crate::backend::{BatchedBackend, ResilientBackend, ShardedBackend};
    pub use crate::bytecode::{disassemble, CodeObject, Instr, IsaVersion};
    pub use crate::decompiler::{decompile, Decompiler};
    pub use crate::dynamo::{Dynamo, DynamoConfig};
    pub use crate::pylang::compile_module;
    pub use crate::runtime::Runtime;
    pub use crate::serve::{AsyncBackend, CallFuture, PipelinedShardedBackend};
    pub use crate::tensor::Tensor;
    pub use crate::value::Value;
    pub use crate::vm::Vm;
}
