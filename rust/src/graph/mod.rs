//! Tensor computation-graph IR — the analogue of torch.fx graphs that
//! Dynamo extracts. Nodes are created by dynamo's symbolic evaluation;
//! shapes are inferred eagerly so capture fails fast on invalid programs.

pub mod opt;
mod printer;
pub mod serde;

pub use opt::{optimize, OptLevel, Optimized, PassStat};
pub use printer::{print_graph, print_graph_with_lines};
pub use serde::{parse_graph, render_graph, GRAPH_SCHEMA_VERSION};

use std::cell::Cell;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Duration;

use crate::api::DepyfError;
use crate::fnv::Fnv;
use crate::tensor::{self, Tensor};

pub type NodeId = usize;

/// Tensor operations representable in a captured graph.
#[derive(Clone, Debug, PartialEq)]
pub enum OpKind {
    // elementwise binary (broadcasting)
    Add,
    Sub,
    Mul,
    Div,
    Pow,
    Maximum,
    Minimum,
    // elementwise unary
    Neg,
    Relu,
    Gelu,
    Tanh,
    Sigmoid,
    Exp,
    Log,
    Sqrt,
    Abs,
    // linear algebra
    MatMul,
    Transpose,
    Reshape(Vec<i64>),
    Permute(Vec<usize>),
    // reductions / normalization
    Softmax,
    Sum(Option<usize>),
    Mean(Option<usize>),
    Max(Option<usize>),
    Min(Option<usize>),
    LayerNorm,
    // NN specifics
    Embedding,
    CrossEntropy,
}

impl OpKind {
    /// The tensor-method name users write (`x.relu()`, `t.matmul(u)`).
    pub fn method_name(&self) -> &'static str {
        match self {
            OpKind::Add => "add",
            OpKind::Sub => "sub",
            OpKind::Mul => "mul",
            OpKind::Div => "div",
            OpKind::Pow => "pow",
            OpKind::Maximum => "maximum",
            OpKind::Minimum => "minimum",
            OpKind::Neg => "neg",
            OpKind::Relu => "relu",
            OpKind::Gelu => "gelu",
            OpKind::Tanh => "tanh",
            OpKind::Sigmoid => "sigmoid",
            OpKind::Exp => "exp",
            OpKind::Log => "log",
            OpKind::Sqrt => "sqrt",
            OpKind::Abs => "abs",
            OpKind::MatMul => "matmul",
            OpKind::Transpose => "t",
            OpKind::Reshape(_) => "reshape",
            OpKind::Permute(_) => "permute",
            OpKind::Softmax => "softmax",
            OpKind::Sum(_) => "sum",
            OpKind::Mean(_) => "mean",
            OpKind::Max(_) => "max",
            OpKind::Min(_) => "min",
            OpKind::LayerNorm => "layernorm",
            OpKind::Embedding => "embedding",
            OpKind::CrossEntropy => "cross_entropy",
        }
    }
}

#[derive(Clone, Debug)]
pub enum NodeKind {
    /// A graph input (lifted local or global tensor).
    Placeholder { name: String },
    /// A Python-number constant that entered tensor compute.
    ConstScalar(f64),
    /// A tensor materialized at capture time (torch.zeros/ones/arange with
    /// constant arguments) embedded as a graph constant.
    ConstTensor(Tensor),
    /// A tensor op over earlier nodes.
    Op(OpKind, Vec<NodeId>),
}

#[derive(Clone, Debug)]
pub struct Node {
    pub kind: NodeKind,
    pub shape: Vec<usize>,
}

/// A captured tensor computation graph.
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub name: String,
    pub nodes: Vec<Node>,
    pub inputs: Vec<NodeId>,
    pub outputs: Vec<NodeId>,
}

impl Graph {
    pub fn new(name: &str) -> Graph {
        Graph { name: name.to_string(), ..Default::default() }
    }

    pub fn placeholder(&mut self, name: &str, shape: &[usize]) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node { kind: NodeKind::Placeholder { name: name.to_string() }, shape: shape.to_vec() });
        self.inputs.push(id);
        id
    }

    pub fn const_scalar(&mut self, v: f64) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node { kind: NodeKind::ConstScalar(v), shape: vec![] });
        id
    }

    pub fn const_tensor(&mut self, t: Tensor) -> NodeId {
        let id = self.nodes.len();
        let shape = t.shape().to_vec();
        self.nodes.push(Node { kind: NodeKind::ConstTensor(t), shape });
        id
    }

    /// Add an op node, inferring (and validating) its output shape.
    pub fn add_op(&mut self, op: OpKind, args: Vec<NodeId>) -> Result<NodeId, DepyfError> {
        let shapes: Vec<&[usize]> = args.iter().map(|&a| self.nodes[a].shape.as_slice()).collect();
        let shape = infer_shape(&op, &shapes)?;
        let id = self.nodes.len();
        self.nodes.push(Node { kind: NodeKind::Op(op, args), shape });
        Ok(id)
    }

    pub fn set_outputs(&mut self, outputs: Vec<NodeId>) {
        self.outputs = outputs;
    }

    pub fn num_ops(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n.kind, NodeKind::Op(..))).count()
    }

    /// A stable structural hash of the graph: node kinds, op kinds (with
    /// their static parameters), shapes, constant payloads and the
    /// input/output wiring — but **not** the graph name. Two graphs built
    /// independently from the same program and shapes hash identically, so
    /// this is the compile-cache key shared across sessions and (via the
    /// on-disk index) across processes; any shape or op change produces a
    /// different key.
    pub fn content_hash(&self) -> u64 {
        let mut h = Fnv::new();
        h.bytes(b"depyf-graph-v1");
        h.num(self.nodes.len() as u64);
        for node in &self.nodes {
            match &node.kind {
                NodeKind::Placeholder { .. } => h.num(0),
                NodeKind::ConstScalar(v) => {
                    h.num(1);
                    h.num(v.to_bits());
                }
                NodeKind::ConstTensor(t) => {
                    h.num(2);
                    h.num(t.rank() as u64);
                    for v in t.data() {
                        h.num(v.to_bits() as u64);
                    }
                }
                NodeKind::Op(op, args) => {
                    h.num(3);
                    hash_op(&mut h, op);
                    h.num(args.len() as u64);
                    for a in args {
                        h.num(*a as u64);
                    }
                }
            }
            h.num(node.shape.len() as u64);
            for d in &node.shape {
                h.num(*d as u64);
            }
        }
        h.num(self.inputs.len() as u64);
        for i in &self.inputs {
            h.num(*i as u64);
        }
        h.num(self.outputs.len() as u64);
        for o in &self.outputs {
            h.num(*o as u64);
        }
        h.finish()
    }

    /// Structural hash of **one** node: kind tag, op kind with static
    /// parameters, const payload bits, argument wiring and output shape.
    /// This is the CSE key in [`opt`]: two op/const nodes hashing equal
    /// (and comparing structurally equal) compute identical values in any
    /// environment. Placeholders hash their own id, so distinct inputs
    /// never collide — each is a separate calling-convention slot.
    pub fn node_structural_hash(&self, id: NodeId) -> u64 {
        let mut h = Fnv::new();
        h.bytes(b"depyf-node-v1");
        let node = &self.nodes[id];
        match &node.kind {
            NodeKind::Placeholder { .. } => {
                h.num(0);
                h.num(id as u64);
            }
            NodeKind::ConstScalar(v) => {
                h.num(1);
                h.num(v.to_bits());
            }
            NodeKind::ConstTensor(t) => {
                h.num(2);
                h.num(t.rank() as u64);
                for v in t.data() {
                    h.num(v.to_bits() as u64);
                }
            }
            NodeKind::Op(op, args) => {
                h.num(3);
                hash_op(&mut h, op);
                h.num(args.len() as u64);
                for a in args {
                    h.num(*a as u64);
                }
            }
        }
        h.num(node.shape.len() as u64);
        for d in &node.shape {
            h.num(*d as u64);
        }
        h.finish()
    }

    /// `(name, shape)` of every placeholder, in input order — the example
    /// input specs a [`crate::api::CompileRequest`] carries.
    pub fn input_shapes(&self) -> Vec<(String, Vec<usize>)> {
        self.inputs
            .iter()
            .map(|&id| match &self.nodes[id].kind {
                NodeKind::Placeholder { name } => (name.clone(), self.nodes[id].shape.clone()),
                other => (format!("<{:?}>", other), self.nodes[id].shape.clone()),
            })
            .collect()
    }

    /// Validate a runtime input list against the placeholder arity and
    /// shapes — the shared precondition of every backend executor.
    pub fn check_inputs(&self, inputs: &[Rc<Tensor>]) -> Result<(), DepyfError> {
        if inputs.len() != self.inputs.len() {
            return Err(DepyfError::Backend(format!(
                "graph {} expects {} inputs, got {}",
                self.name,
                self.inputs.len(),
                inputs.len()
            )));
        }
        for (slot, input) in self.inputs.iter().zip(inputs.iter()) {
            let node = &self.nodes[*slot];
            if node.shape != input.shape() {
                return Err(DepyfError::Backend(format!(
                    "graph {} input {} shape mismatch: expected {:?}, got {:?}",
                    self.name,
                    slot,
                    node.shape,
                    input.shape()
                )));
            }
        }
        Ok(())
    }

    /// Approximate FLOP count (matmuls dominate).
    pub fn flops(&self) -> u64 {
        let mut total = 0u64;
        for n in &self.nodes {
            if let NodeKind::Op(OpKind::MatMul, args) = &n.kind {
                let a = &self.nodes[args[0]].shape;
                let k = *a.last().unwrap_or(&1) as u64;
                total += 2 * k * n.shape.iter().product::<usize>() as u64;
            } else if let NodeKind::Op(_, _) = &n.kind {
                total += n.shape.iter().product::<usize>() as u64;
            }
        }
        total
    }
}

/// Hash an op kind including its static parameters, so `Sum(None)` vs
/// `Sum(Some(0))` or `Reshape([2,3])` vs `Reshape([3,2])` differ.
fn hash_op(h: &mut Fnv, op: &OpKind) {
    fn axis(h: &mut Fnv, ax: &Option<usize>) {
        match ax {
            None => h.num(0),
            Some(a) => {
                h.num(1);
                h.num(*a as u64);
            }
        }
    }
    match op {
        OpKind::Add => h.num(1),
        OpKind::Sub => h.num(2),
        OpKind::Mul => h.num(3),
        OpKind::Div => h.num(4),
        OpKind::Pow => h.num(5),
        OpKind::Maximum => h.num(6),
        OpKind::Minimum => h.num(7),
        OpKind::Neg => h.num(8),
        OpKind::Relu => h.num(9),
        OpKind::Gelu => h.num(10),
        OpKind::Tanh => h.num(11),
        OpKind::Sigmoid => h.num(12),
        OpKind::Exp => h.num(13),
        OpKind::Log => h.num(14),
        OpKind::Sqrt => h.num(15),
        OpKind::Abs => h.num(16),
        OpKind::MatMul => h.num(17),
        OpKind::Transpose => h.num(18),
        OpKind::Reshape(spec) => {
            h.num(19);
            h.num(spec.len() as u64);
            for d in spec {
                h.num(*d as u64);
            }
        }
        OpKind::Permute(perm) => {
            h.num(20);
            h.num(perm.len() as u64);
            for p in perm {
                h.num(*p as u64);
            }
        }
        OpKind::Softmax => h.num(21),
        OpKind::Sum(ax) => {
            h.num(22);
            axis(h, ax);
        }
        OpKind::Mean(ax) => {
            h.num(23);
            axis(h, ax);
        }
        OpKind::Max(ax) => {
            h.num(24);
            axis(h, ax);
        }
        OpKind::Min(ax) => {
            h.num(25);
            axis(h, ax);
        }
        OpKind::LayerNorm => h.num(26),
        OpKind::Embedding => h.num(27),
        OpKind::CrossEntropy => h.num(28),
    }
}

/// Output-shape inference for each op.
pub fn infer_shape(op: &OpKind, shapes: &[&[usize]]) -> Result<Vec<usize>, DepyfError> {
    let need = |n: usize| -> Result<(), DepyfError> {
        if shapes.len() != n {
            Err(DepyfError::Compile(format!("{:?} expects {} args, got {}", op, n, shapes.len())))
        } else {
            Ok(())
        }
    };
    match op {
        OpKind::Add | OpKind::Sub | OpKind::Mul | OpKind::Div | OpKind::Pow | OpKind::Maximum | OpKind::Minimum => {
            need(2)?;
            tensor::broadcast_shapes(shapes[0], shapes[1]).map_err(|e| DepyfError::Compile(e.to_string()))
        }
        OpKind::Neg
        | OpKind::Relu
        | OpKind::Gelu
        | OpKind::Tanh
        | OpKind::Sigmoid
        | OpKind::Exp
        | OpKind::Log
        | OpKind::Sqrt
        | OpKind::Abs
        | OpKind::Softmax => {
            need(1)?;
            Ok(shapes[0].to_vec())
        }
        OpKind::MatMul => {
            need(2)?;
            let (a, b) = (shapes[0], shapes[1]);
            if a.len() < 2 || b.len() < 2 {
                return Err(DepyfError::Compile(format!("matmul needs rank>=2, got {:?} @ {:?}", a, b)));
            }
            if a[a.len() - 1] != b[b.len() - 2] {
                return Err(DepyfError::Compile(format!("matmul inner-dim mismatch: {:?} @ {:?}", a, b)));
            }
            let batch = if a.len() >= b.len() { &a[..a.len() - 2] } else { &b[..b.len() - 2] };
            if a.len() > 2 && b.len() > 2 && a[..a.len() - 2] != b[..b.len() - 2] {
                return Err(DepyfError::Compile(format!("matmul batch mismatch: {:?} @ {:?}", a, b)));
            }
            let mut s = batch.to_vec();
            s.push(a[a.len() - 2]);
            s.push(b[b.len() - 1]);
            Ok(s)
        }
        OpKind::Transpose => {
            need(1)?;
            let a = shapes[0];
            if a.len() < 2 {
                return Err(DepyfError::Compile(format!("transpose needs rank>=2, got {:?}", a)));
            }
            let mut s = a.to_vec();
            let r = s.len();
            s.swap(r - 2, r - 1);
            Ok(s)
        }
        OpKind::Reshape(spec) => {
            need(1)?;
            let numel: usize = shapes[0].iter().product();
            tensor::reshape_infer(numel, spec).map_err(|e| DepyfError::Compile(e.to_string()))
        }
        OpKind::Permute(perm) => {
            need(1)?;
            if perm.len() != shapes[0].len() {
                return Err(DepyfError::Compile(format!("permute {:?} on rank-{}", perm, shapes[0].len())));
            }
            Ok(perm.iter().map(|&p| shapes[0][p]).collect())
        }
        OpKind::Sum(axis) | OpKind::Mean(axis) | OpKind::Max(axis) | OpKind::Min(axis) => {
            need(1)?;
            match axis {
                None => Ok(vec![]),
                Some(ax) => {
                    if *ax >= shapes[0].len() {
                        return Err(DepyfError::Compile(format!(
                            "reduce axis {} out of range for {:?}",
                            ax, shapes[0]
                        )));
                    }
                    let mut s = shapes[0].to_vec();
                    s.remove(*ax);
                    Ok(s)
                }
            }
        }
        OpKind::LayerNorm => {
            need(3)?;
            let n = *shapes[0]
                .last()
                .ok_or_else(|| DepyfError::Compile("layernorm on rank-0".into()))?;
            if shapes[1] != [n] || shapes[2] != [n] {
                return Err(DepyfError::Compile(format!(
                    "layernorm params must be [{}], got {:?} {:?}",
                    n, shapes[1], shapes[2]
                )));
            }
            Ok(shapes[0].to_vec())
        }
        OpKind::Embedding => {
            need(2)?;
            if shapes[0].len() != 2 {
                return Err(DepyfError::Compile(format!("embedding table must be rank 2, got {:?}", shapes[0])));
            }
            let mut s = shapes[1].to_vec();
            s.push(shapes[0][1]);
            Ok(s)
        }
        OpKind::CrossEntropy => {
            need(2)?;
            if shapes[0].is_empty() {
                return Err(DepyfError::Compile("cross_entropy on rank-0 logits".into()));
            }
            let rows: usize = shapes[0][..shapes[0].len() - 1].iter().product();
            let trows: usize = shapes[1].iter().product();
            if rows != trows {
                return Err(DepyfError::Compile(format!("cross_entropy rows {} vs targets {}", rows, trows)));
            }
            Ok(vec![])
        }
    }
}

/// Dispatch-path resilience counters, shared (via `Arc`) between every
/// compiled fn a session installs so the session can fold them into its
/// metrics snapshot once. All atomics: compiled fns are dispatched from
/// serving threads.
#[derive(Debug, Default)]
pub struct CallCounters {
    /// Transient call failures retried on the same module.
    pub retries: AtomicU64,
    /// Calls served by the eager fallback after the module failed.
    pub degraded_calls: AtomicU64,
    /// Calls abandoned at their deadline (then served by the fallback).
    pub timeouts: AtomicU64,
    /// Module-call panics converted to [`DepyfError::Panic`].
    pub panics_caught: AtomicU64,
}

impl CallCounters {
    /// Accumulate these counters into a metrics snapshot (the session /
    /// serve driver calls this once per snapshot).
    pub fn fold_into(&self, snap: &mut crate::metrics::MetricsSnapshot) {
        snap.retries += self.retries.load(Ordering::Relaxed);
        snap.degraded_calls += self.degraded_calls.load(Ordering::Relaxed);
        snap.timeouts += self.timeouts.load(Ordering::Relaxed);
        snap.panics_caught += self.panics_caught.load(Ordering::Relaxed);
    }
}

/// Call-time resilience configuration attached by dynamo (see
/// [`CompiledGraphFn::with_resilience`]): what to do when a dispatched
/// call fails, panics or outlives its deadline.
pub struct CallResilience {
    /// [`crate::api::FallbackPolicy::Eager`] serves failed calls from a
    /// lazily-built eager fallback module; `Error` propagates.
    pub fallback: crate::api::FallbackPolicy,
    /// Abandon calls that run longer than this (the call is watchdogged
    /// on a helper thread; the abandoned worker finishes harmlessly).
    pub deadline: Option<Duration>,
    /// Transient-failure retries on the same module before degrading.
    pub max_retries: u32,
    pub counters: Arc<CallCounters>,
}

impl CallResilience {
    /// One retry, the given policy/deadline, counters shared with the
    /// session.
    pub fn new(
        fallback: crate::api::FallbackPolicy,
        deadline: Option<Duration>,
        counters: Arc<CallCounters>,
    ) -> CallResilience {
        CallResilience { fallback, deadline, max_retries: 1, counters }
    }
}

/// A compiled graph installed by dynamo as a callable global
/// (`__compiled_fn_N`). Dispatches tensor inputs through the backend's
/// [`crate::api::CompiledModule`], which also carries the per-partition
/// artifacts and stats the session dumps at `finish()`.
///
/// Dispatch is panic-isolated: `call` runs the module under
/// `catch_unwind`, so a panicking backend executor becomes
/// [`DepyfError::Panic`] instead of unwinding through the VM (and never
/// poisons shared locks). With [`CallResilience`] attached, transient
/// failures are retried, deadlines abandon stuck calls, and final
/// failures degrade to a lazily-built eager fallback module that is
/// bitwise-equal to the reference executor.
pub struct CompiledGraphFn {
    pub name: String,
    pub graph: Arc<Graph>,
    /// Which backend compiled this (for dumps/metrics).
    pub backend_name: String,
    /// The backend's executable module (lowered via `Backend::lower`).
    pub module: Arc<dyn crate::api::CompiledModule>,
    pub calls: Cell<u64>,
    /// Call-time retry/degrade/deadline behavior (None: isolation only).
    resilience: Option<CallResilience>,
    /// The eager fallback module, built on first degraded call.
    fallback_module: OnceLock<Arc<dyn crate::api::CompiledModule>>,
}

impl CompiledGraphFn {
    /// Wrap a lowered module; `backend_name` is stamped from the module.
    pub fn from_module(
        name: &str,
        graph: Arc<Graph>,
        module: Arc<dyn crate::api::CompiledModule>,
    ) -> CompiledGraphFn {
        CompiledGraphFn {
            name: name.to_string(),
            backend_name: module.backend_name().to_string(),
            graph,
            module,
            calls: Cell::new(0),
            resilience: None,
            fallback_module: OnceLock::new(),
        }
    }

    /// Attach call-time resilience (dynamo does this from its config).
    pub fn with_resilience(mut self, res: CallResilience) -> CompiledGraphFn {
        self.resilience = Some(res);
        self
    }

    pub fn call(&self, inputs: &[Rc<Tensor>]) -> Result<Vec<Tensor>, DepyfError> {
        self.calls.set(self.calls.get() + 1);
        match &self.resilience {
            None => self.dispatch_caught(inputs, None),
            Some(res) => self.call_resilient(res, inputs),
        }
    }

    /// One panic-isolated dispatch on the calling thread. The fault gate
    /// sits *inside* the `catch_unwind` so injected panics exercise the
    /// isolation path like real ones. `AssertUnwindSafe` is sound: every
    /// shared lock below recovers from poison, and this `&self` borrow
    /// holds no interior state a panic could tear.
    fn dispatch_caught(
        &self,
        inputs: &[Rc<Tensor>],
        counters: Option<&CallCounters>,
    ) -> Result<Vec<Tensor>, DepyfError> {
        catch_unwind(AssertUnwindSafe(|| {
            crate::faults::gate(crate::faults::Site::ModuleCall)?;
            self.module.call(inputs)
        }))
        .unwrap_or_else(|payload| {
            if let Some(c) = counters {
                c.panics_caught.fetch_add(1, Ordering::Relaxed);
            }
            Err(DepyfError::from_panic(&format!("module {} ({})", self.name, self.backend_name), payload))
        })
    }

    /// Deadlined dispatch. A [`deadline_aware`] module (async, pipelined)
    /// is trusted to bound its own call: the deadline is published on the
    /// calling thread via [`crate::serve::with_deadline`] — where it also
    /// propagates into queue admission, stage packets and the compile
    /// path — and the call runs inline, no sidecar thread. Everything
    /// else gets the watchdog: the module runs on a helper thread; if it
    /// misses the deadline the call is abandoned (the worker finishes
    /// harmlessly — its `send` to a dropped receiver is a no-op) and the
    /// caller degrades instead of hanging.
    ///
    /// [`deadline_aware`]: crate::api::CompiledModule::deadline_aware
    fn dispatch_deadline(
        &self,
        inputs: &[Rc<Tensor>],
        deadline: Duration,
        counters: &Arc<CallCounters>,
    ) -> Result<Vec<Tensor>, DepyfError> {
        if self.module.deadline_aware() {
            let result = crate::serve::with_deadline(crate::serve::Deadline::after(deadline), || {
                self.dispatch_caught(inputs, Some(counters))
            });
            if let Err(DepyfError::Timeout(_)) = &result {
                counters.timeouts.fetch_add(1, Ordering::Relaxed);
            }
            return result;
        }
        let owned: Vec<Tensor> = inputs.iter().map(|t| (**t).clone()).collect();
        let module = Arc::clone(&self.module);
        let context = format!("module {} ({})", self.name, self.backend_name);
        let counters_in = Arc::clone(counters);
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let handles: Vec<Rc<Tensor>> = owned.into_iter().map(Rc::new).collect();
            let result = catch_unwind(AssertUnwindSafe(|| {
                crate::faults::gate(crate::faults::Site::ModuleCall)?;
                module.call(&handles)
            }))
            .unwrap_or_else(|payload| {
                counters_in.panics_caught.fetch_add(1, Ordering::Relaxed);
                Err(DepyfError::from_panic(&context, payload))
            });
            let _ = tx.send(result);
        });
        match rx.recv_timeout(deadline) {
            Ok(result) => result,
            Err(_) => {
                counters.timeouts.fetch_add(1, Ordering::Relaxed);
                Err(DepyfError::Timeout(format!(
                    "module {} ({}) exceeded its {:?} deadline; call abandoned",
                    self.name, self.backend_name, deadline
                )))
            }
        }
    }

    fn call_resilient(
        &self,
        res: &CallResilience,
        inputs: &[Rc<Tensor>],
    ) -> Result<Vec<Tensor>, DepyfError> {
        let mut tries = 0u32;
        let final_err = loop {
            let result = match res.deadline {
                None => self.dispatch_caught(inputs, Some(&res.counters)),
                Some(d) => self.dispatch_deadline(inputs, d, &res.counters),
            };
            match result {
                Ok(out) => return Ok(out),
                // A timed-out call is abandoned, not retried: the module
                // is presumed stuck, so go straight to the fallback.
                Err(e @ DepyfError::Timeout(_)) => break e,
                Err(e) if e.is_transient() && tries < res.max_retries => {
                    tries += 1;
                    res.counters.retries.fetch_add(1, Ordering::Relaxed);
                }
                Err(e) => break e,
            }
        };
        match res.fallback {
            crate::api::FallbackPolicy::Error => Err(final_err),
            crate::api::FallbackPolicy::Eager => {
                let fb = self.eager_fallback();
                let fb_result = catch_unwind(AssertUnwindSafe(|| fb.call(inputs)))
                    .unwrap_or_else(|payload| Err(DepyfError::from_panic("eager fallback", payload)));
                match fb_result {
                    Ok(out) => {
                        res.counters.degraded_calls.fetch_add(1, Ordering::Relaxed);
                        // Let recording wrappers capture the degraded call
                        // (with the backend that actually served it).
                        self.module.record_degraded(inputs, &out, fb.backend_name());
                        Ok(out)
                    }
                    // The fallback failing too means the inputs (not the
                    // backend) are bad: report the original failure.
                    Err(_) => Err(final_err),
                }
            }
        }
    }

    /// The lazily-built eager fallback: the *unoptimized, unfused*
    /// reference executor over this fn's captured graph — bitwise-equal
    /// to the conformance oracle, usable even when the optimized module
    /// is what is failing.
    fn eager_fallback(&self) -> Arc<dyn crate::api::CompiledModule> {
        Arc::clone(self.fallback_module.get_or_init(|| {
            Arc::new(crate::backend::eager::EagerModule::with_fusion(
                Arc::clone(&self.graph),
                format!("eager ({} call fallback)", self.backend_name),
                false,
            ))
        }))
    }
}

impl fmt::Debug for CompiledGraphFn {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "<compiled graph {} via {}, {} calls>", self.name, self.backend_name, self.calls.get())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_small_graph() {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2, 3]);
        let y = g.placeholder("y", &[3, 4]);
        let m = g.add_op(OpKind::MatMul, vec![x, y]).unwrap();
        assert_eq!(g.nodes[m].shape, vec![2, 4]);
        let r = g.add_op(OpKind::Relu, vec![m]).unwrap();
        g.set_outputs(vec![r]);
        assert_eq!(g.num_ops(), 2);
        assert!(g.flops() >= 2 * 3 * 2 * 4);
    }

    #[test]
    fn shape_errors_at_capture() {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2, 3]);
        let y = g.placeholder("y", &[2, 3]);
        assert!(g.add_op(OpKind::MatMul, vec![x, y]).is_err());
        assert!(g.add_op(OpKind::Sum(Some(5)), vec![x]).is_err());
    }

    #[test]
    fn broadcast_shape_inference() {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[4, 1]);
        let y = g.placeholder("y", &[3]);
        let s = g.add_op(OpKind::Add, vec![x, y]).unwrap();
        assert_eq!(g.nodes[s].shape, vec![4, 3]);
    }

    #[test]
    fn reduction_and_reshape() {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2, 6]);
        let r = g.add_op(OpKind::Reshape(vec![3, -1]), vec![x]).unwrap();
        assert_eq!(g.nodes[r].shape, vec![3, 4]);
        let s = g.add_op(OpKind::Sum(Some(1)), vec![r]).unwrap();
        assert_eq!(g.nodes[s].shape, vec![3]);
        let t = g.add_op(OpKind::Sum(None), vec![s]).unwrap();
        assert_eq!(g.nodes[t].shape, Vec::<usize>::new());
    }

    fn build(name: &str, shape: &[usize], relu: bool, axis: Option<usize>) -> Graph {
        let mut g = Graph::new(name);
        let x = g.placeholder("x", shape);
        let c = g.const_scalar(2.0);
        let m = g.add_op(OpKind::Mul, vec![x, c]).unwrap();
        let a = if relu {
            g.add_op(OpKind::Relu, vec![m]).unwrap()
        } else {
            g.add_op(OpKind::Gelu, vec![m]).unwrap()
        };
        let s = g.add_op(OpKind::Sum(axis), vec![a]).unwrap();
        g.set_outputs(vec![s]);
        g
    }

    #[test]
    fn content_hash_is_stable_across_rebuilds() {
        let a = build("first", &[2, 3], true, None);
        let b = build("totally_different_name", &[2, 3], true, None);
        assert_eq!(a.content_hash(), b.content_hash(), "name must not affect the hash");
        assert_eq!(a.content_hash(), build("first", &[2, 3], true, None).content_hash());
    }

    #[test]
    fn content_hash_changes_with_shapes_ops_and_params() {
        let base = build("g", &[2, 3], true, None).content_hash();
        assert_ne!(base, build("g", &[3, 2], true, None).content_hash(), "shape change");
        assert_ne!(base, build("g", &[2, 3], false, None).content_hash(), "op-kind change");
        assert_ne!(base, build("g", &[2, 3], true, Some(0)).content_hash(), "axis param change");
        // Constant payloads matter too.
        let mut g1 = Graph::new("g");
        let t1 = g1.const_tensor(Tensor::new(vec![2], vec![1.0, 2.0]));
        g1.set_outputs(vec![t1]);
        let mut g2 = Graph::new("g");
        let t2 = g2.const_tensor(Tensor::new(vec![2], vec![1.0, 3.0]));
        g2.set_outputs(vec![t2]);
        assert_ne!(g1.content_hash(), g2.content_hash());
    }

    #[test]
    fn embedding_and_ce() {
        let mut g = Graph::new("g");
        let tb = g.placeholder("table", &[10, 4]);
        let ids = g.placeholder("ids", &[2, 3]);
        let e = g.add_op(OpKind::Embedding, vec![tb, ids]).unwrap();
        assert_eq!(g.nodes[e].shape, vec![2, 3, 4]);
        let logits = g.placeholder("logits", &[6, 10]);
        let tgt = g.placeholder("tgt", &[6]);
        let ce = g.add_op(OpKind::CrossEntropy, vec![logits, tgt]).unwrap();
        assert_eq!(g.nodes[ce].shape, Vec::<usize>::new());
    }

    fn relu_graph() -> Arc<Graph> {
        let mut g = Graph::new("f");
        let x = g.placeholder("x", &[2]);
        let r = g.add_op(OpKind::Relu, vec![x]).unwrap();
        g.set_outputs(vec![r]);
        Arc::new(g)
    }

    /// A module whose `call` misbehaves on demand.
    struct Broken {
        mode: &'static str, // "panic" | "error" | "stuck"
    }

    impl crate::api::CompiledModule for Broken {
        fn call(&self, _inputs: &[Rc<Tensor>]) -> Result<Vec<Tensor>, DepyfError> {
            match self.mode {
                "panic" => panic!("executor bug"),
                "stuck" => {
                    std::thread::sleep(Duration::from_millis(300));
                    Err(DepyfError::Runtime("finished too late to matter".into()))
                }
                _ => Err(DepyfError::Runtime("transient executor failure".into())),
            }
        }
        fn backend_name(&self) -> &str {
            "broken"
        }
    }

    #[test]
    fn compiled_fn_isolates_module_panics() {
        let f = CompiledGraphFn::from_module("f", relu_graph(), Arc::new(Broken { mode: "panic" }));
        let err = f.call(&[Rc::new(Tensor::new(vec![2], vec![1.0, -1.0]))]).unwrap_err();
        assert_eq!(err.layer(), "panic");
        assert!(err.to_string().contains("module f (broken) panicked: executor bug"), "{}", err);
        assert_eq!(f.calls.get(), 1);
    }

    #[test]
    fn resilient_call_retries_then_degrades_to_bitwise_eager() {
        let counters = Arc::new(CallCounters::default());
        let f = CompiledGraphFn::from_module("f", relu_graph(), Arc::new(Broken { mode: "error" }))
            .with_resilience(CallResilience::new(
                crate::api::FallbackPolicy::Eager,
                None,
                Arc::clone(&counters),
            ));
        let out = f.call(&[Rc::new(Tensor::new(vec![2], vec![1.0, -1.0]))]).unwrap();
        assert_eq!(out[0].data(), &[1.0, 0.0], "fallback must be the eager reference result");
        assert_eq!(counters.retries.load(Ordering::Relaxed), 1, "one retry before degrading");
        assert_eq!(counters.degraded_calls.load(Ordering::Relaxed), 1);
        assert_eq!(counters.timeouts.load(Ordering::Relaxed), 0);
        // Panicking modules degrade the same way, counting the panic.
        let f = CompiledGraphFn::from_module("f", relu_graph(), Arc::new(Broken { mode: "panic" }))
            .with_resilience(CallResilience::new(
                crate::api::FallbackPolicy::Eager,
                None,
                Arc::clone(&counters),
            ));
        let out = f.call(&[Rc::new(Tensor::new(vec![2], vec![-2.0, 3.0]))]).unwrap();
        assert_eq!(out[0].data(), &[0.0, 3.0]);
        assert_eq!(counters.panics_caught.load(Ordering::Relaxed), 2, "initial call + retry");
    }

    #[test]
    fn resilient_call_propagates_under_error_policy() {
        let counters = Arc::new(CallCounters::default());
        let f = CompiledGraphFn::from_module("f", relu_graph(), Arc::new(Broken { mode: "error" }))
            .with_resilience(CallResilience::new(
                crate::api::FallbackPolicy::Error,
                None,
                Arc::clone(&counters),
            ));
        let err = f.call(&[Rc::new(Tensor::new(vec![2], vec![1.0, -1.0]))]).unwrap_err();
        assert_eq!(err.layer(), "runtime");
        assert_eq!(counters.retries.load(Ordering::Relaxed), 1);
        assert_eq!(counters.degraded_calls.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn deadline_abandons_stuck_call_and_serves_fallback() {
        let counters = Arc::new(CallCounters::default());
        let f = CompiledGraphFn::from_module("f", relu_graph(), Arc::new(Broken { mode: "stuck" }))
            .with_resilience(CallResilience::new(
                crate::api::FallbackPolicy::Eager,
                Some(Duration::from_millis(25)),
                Arc::clone(&counters),
            ));
        let t0 = std::time::Instant::now();
        let out = f.call(&[Rc::new(Tensor::new(vec![2], vec![4.0, -4.0]))]).unwrap();
        assert_eq!(out[0].data(), &[4.0, 0.0]);
        assert!(t0.elapsed() < Duration::from_millis(250), "abandon, don't wait out the stuck call");
        assert_eq!(counters.timeouts.load(Ordering::Relaxed), 1);
        assert_eq!(counters.degraded_calls.load(Ordering::Relaxed), 1);
        assert_eq!(counters.retries.load(Ordering::Relaxed), 0, "timeouts are not retried");
    }
}
