//! Pretty-print a captured graph as runnable-looking Python source — the
//! `__compiled_fn_N.py` dump of Figure 2. Line numbers in the emitted text
//! are stable, so the debugger can map executor progress to dump lines.

use std::collections::HashMap;

use super::{Graph, NodeKind, OpKind};

/// Render the graph as a Python-like function definition.
///
/// Thin wrapper over [`print_graph_with_lines`] for callers that only need
/// the text.
pub fn print_graph(g: &Graph) -> String {
    print_graph_with_lines(g).0
}

/// Render the graph and return, alongside the text, the line table mapping
/// op-node id → 1-based line in the rendered text. The table is recorded
/// *while emitting*, so it is the single source of truth for dump layout —
/// the debugger's graph stops and `hijack`'s dumps both consume it.
pub fn print_graph_with_lines(g: &Graph) -> (String, HashMap<usize, u32>) {
    let mut out = String::new();
    let mut lines: HashMap<usize, u32> = HashMap::new();
    let mut line = 1u32; // the `def ...` header
    let arg_names: Vec<String> = g
        .inputs
        .iter()
        .map(|&i| match &g.nodes[i].kind {
            NodeKind::Placeholder { name } => name.clone(),
            _ => format!("v{}", i),
        })
        .collect();
    out.push_str(&format!("def {}({}):\n", g.name, arg_names.join(", ")));
    let var = |id: usize| -> String {
        match &g.nodes[id].kind {
            NodeKind::Placeholder { name } => name.clone(),
            NodeKind::ConstScalar(v) => {
                if v.fract() == 0.0 && v.abs() < 1e16 {
                    format!("{:.1}", v)
                } else {
                    format!("{}", v)
                }
            }
            NodeKind::ConstTensor(t) => format!("torch.const(shape={:?})", t.shape()),
            NodeKind::Op(..) => format!("v{}", id),
        }
    };
    for (id, node) in g.nodes.iter().enumerate() {
        if let NodeKind::Op(op, args) = &node.kind {
            let expr = match op {
                OpKind::Add => format!("{} + {}", var(args[0]), var(args[1])),
                OpKind::Sub => format!("{} - {}", var(args[0]), var(args[1])),
                OpKind::Mul => format!("{} * {}", var(args[0]), var(args[1])),
                OpKind::Div => format!("{} / {}", var(args[0]), var(args[1])),
                OpKind::Pow => format!("{} ** {}", var(args[0]), var(args[1])),
                OpKind::MatMul => format!("{} @ {}", var(args[0]), var(args[1])),
                OpKind::Neg => format!("-{}", var(args[0])),
                OpKind::Maximum => format!("torch.maximum({}, {})", var(args[0]), var(args[1])),
                OpKind::Minimum => format!("torch.minimum({}, {})", var(args[0]), var(args[1])),
                OpKind::Reshape(spec) => {
                    let dims: Vec<String> = spec.iter().map(|d| d.to_string()).collect();
                    format!("{}.reshape([{}])", var(args[0]), dims.join(", "))
                }
                OpKind::Permute(perm) => {
                    let dims: Vec<String> = perm.iter().map(|d| d.to_string()).collect();
                    format!("{}.permute([{}])", var(args[0]), dims.join(", "))
                }
                OpKind::Sum(ax) | OpKind::Mean(ax) | OpKind::Max(ax) | OpKind::Min(ax) => {
                    let m = op.method_name();
                    match ax {
                        Some(a) => format!("{}.{}({})", var(args[0]), m, a),
                        None => format!("{}.{}()", var(args[0]), m),
                    }
                }
                OpKind::LayerNorm => format!("torch.layernorm({}, {}, {})", var(args[0]), var(args[1]), var(args[2])),
                OpKind::Embedding => format!("torch.embedding({}, {})", var(args[0]), var(args[1])),
                OpKind::CrossEntropy => format!("torch.cross_entropy({}, {})", var(args[0]), var(args[1])),
                // simple unary methods
                _ => format!("{}.{}()", var(args[0]), op.method_name()),
            };
            line += 1;
            lines.insert(id, line);
            out.push_str(&format!("    v{} = {}  # shape: {:?}\n", id, expr, node.shape));
        }
    }
    let outs: Vec<String> = g.outputs.iter().map(|&o| var(o)).collect();
    out.push_str(&format!("    return ({},)\n", outs.join(", ")));
    (out, lines)
}

#[cfg(test)]
mod tests {
    use super::super::{Graph, OpKind};
    use super::*;

    #[test]
    fn printed_graph_mentions_ops_and_shapes() {
        let mut g = Graph::new("__compiled_fn_0");
        let x = g.placeholder("l_x_", &[2, 3]);
        let y = g.placeholder("l_y_", &[3, 4]);
        let m = g.add_op(OpKind::MatMul, vec![x, y]).unwrap();
        let r = g.add_op(OpKind::Relu, vec![m]).unwrap();
        g.set_outputs(vec![r]);
        let s = print_graph(&g);
        assert!(s.contains("def __compiled_fn_0(l_x_, l_y_):"));
        assert!(s.contains("l_x_ @ l_y_"));
        assert!(s.contains(".relu()"));
        assert!(s.contains("[2, 4]"));
        assert!(s.trim_end().ends_with("return (v3,)"));
    }

    #[test]
    fn line_table_matches_emitted_text() {
        let mut g = Graph::new("__compiled_fn_0");
        let x = g.placeholder("x", &[2]);
        let a = g.add_op(OpKind::Relu, vec![x]).unwrap();
        let b = g.add_op(OpKind::Exp, vec![a]).unwrap();
        g.set_outputs(vec![b]);
        let (text, table) = print_graph_with_lines(&g);
        assert_eq!(table[&a], 2);
        assert_eq!(table[&b], 3);
        // cross-check against the printed text (1-based lines)
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines[(table[&a] - 1) as usize].contains(&format!("v{} =", a)));
        assert!(lines[(table[&b] - 1) as usize].contains(&format!("v{} =", b)));
        // placeholders never appear in the table
        assert!(!table.contains_key(&x));
    }

    #[test]
    fn scalar_consts_inline() {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2]);
        let c = g.const_scalar(2.0);
        let m = g.add_op(OpKind::Mul, vec![x, c]).unwrap();
        g.set_outputs(vec![m]);
        let s = print_graph(&g);
        assert!(s.contains("x * 2.0"));
    }
}
