//! Lossless text serialization of captured graphs.
//!
//! Trace bundles (`__trace_*.json`, see [`crate::api::trace`]) must be
//! **self-contained**: a bundle replayed on another machine, or long after
//! the recording session exited, needs the exact graph that was compiled —
//! not a pretty-printed approximation. [`render_graph`] therefore encodes
//! every float as its raw bit pattern (8 hex digits per f32, 16 per f64),
//! so `parse(render(g))` rebuilds a graph with the **same
//! [`Graph::content_hash`]** — the round-trip is bit-exact, not
//! display-precision. Op shapes are re-inferred on parse and checked
//! against the recorded ones, so a corrupted bundle fails loudly instead
//! of replaying a different computation.

use crate::api::json::{self, Json};
use crate::api::DepyfError;
use crate::tensor::Tensor;

use super::{Graph, NodeKind, OpKind};

/// Bumped whenever the graph JSON schema changes shape.
pub const GRAPH_SCHEMA_VERSION: u64 = 1;

/// Encode f32 payloads as concatenated 8-hex-digit bit patterns — lossless
/// (NaN payloads and -0.0 included), compact, and trivially chunkable.
pub fn f32s_to_hex(data: &[f32]) -> String {
    let mut out = String::with_capacity(data.len() * 8);
    for v in data {
        out.push_str(&format!("{:08x}", v.to_bits()));
    }
    out
}

/// Inverse of [`f32s_to_hex`].
pub fn f32s_from_hex(s: &str) -> Result<Vec<f32>, DepyfError> {
    if s.len() % 8 != 0 {
        return Err(DepyfError::Parse(format!(
            "f32 hex payload length {} is not a multiple of 8",
            s.len()
        )));
    }
    s.as_bytes()
        .chunks(8)
        .map(|chunk| {
            let part = std::str::from_utf8(chunk)
                .map_err(|_| DepyfError::Parse("f32 hex payload is not ASCII".into()))?;
            u32::from_str_radix(part, 16)
                .map(f32::from_bits)
                .map_err(|e| DepyfError::Parse(format!("bad f32 hex '{}': {}", part, e)))
        })
        .collect()
}

fn render_usizes(ids: &[usize]) -> String {
    let inner: Vec<String> = ids.iter().map(|i| i.to_string()).collect();
    format!("[{}]", inner.join(", "))
}

/// Render a graph as a JSON object (no trailing newline) suitable for
/// embedding in a larger document (the trace bundle) or standing alone.
pub fn render_graph(g: &Graph) -> String {
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"schema_version\": {},\n", GRAPH_SCHEMA_VERSION));
    out.push_str(&format!("  \"name\": \"{}\",\n", json::escape(&g.name)));
    out.push_str("  \"nodes\": [\n");
    for (i, node) in g.nodes.iter().enumerate() {
        let body = match &node.kind {
            NodeKind::Placeholder { name } => format!(
                "\"kind\": \"placeholder\", \"pname\": \"{}\", \"shape\": {}",
                json::escape(name),
                render_usizes(&node.shape)
            ),
            NodeKind::ConstScalar(v) => format!(
                "\"kind\": \"const_scalar\", \"bits\": \"{:016x}\"",
                v.to_bits()
            ),
            NodeKind::ConstTensor(t) => format!(
                "\"kind\": \"const_tensor\", \"shape\": {}, \"data\": \"{}\"",
                render_usizes(t.shape()),
                f32s_to_hex(t.data())
            ),
            NodeKind::Op(op, args) => format!(
                "\"kind\": \"op\", \"op\": \"{}\"{}, \"args\": {}, \"shape\": {}",
                op.method_name(),
                render_op_params(op),
                render_usizes(args),
                render_usizes(&node.shape)
            ),
        };
        out.push_str(&format!("    {{{}}}{}\n", body, if i + 1 < g.nodes.len() { "," } else { "" }));
    }
    out.push_str("  ],\n");
    out.push_str(&format!("  \"inputs\": {},\n", render_usizes(&g.inputs)));
    out.push_str(&format!("  \"outputs\": {}\n", render_usizes(&g.outputs)));
    out.push('}');
    out
}

fn render_op_params(op: &OpKind) -> String {
    match op {
        OpKind::Reshape(spec) => {
            let inner: Vec<String> = spec.iter().map(|d| d.to_string()).collect();
            format!(", \"spec\": [{}]", inner.join(", "))
        }
        OpKind::Permute(perm) => format!(", \"perm\": {}", render_usizes(perm)),
        OpKind::Sum(Some(ax)) | OpKind::Mean(Some(ax)) | OpKind::Max(Some(ax)) | OpKind::Min(Some(ax)) => {
            format!(", \"axis\": {}", ax)
        }
        _ => String::new(),
    }
}

/// Parse a graph from a standalone JSON document.
pub fn parse_graph(text: &str) -> Result<Graph, DepyfError> {
    graph_from_value(&json::parse(text)?)
}

/// Rebuild a graph from an already-parsed JSON object (used by the trace
/// bundle parser, which embeds the graph in a larger document).
pub fn graph_from_value(doc: &Json) -> Result<Graph, DepyfError> {
    if let Some(Json::Num(v)) = doc.get("schema_version") {
        if *v != GRAPH_SCHEMA_VERSION as f64 {
            return Err(DepyfError::Parse(format!(
                "unsupported graph schema_version {} (expected {})",
                v, GRAPH_SCHEMA_VERSION
            )));
        }
    }
    let name = doc
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| DepyfError::Parse("graph missing string \"name\"".into()))?;
    let nodes = match doc.get("nodes") {
        Some(Json::Arr(items)) => items,
        _ => return Err(DepyfError::Parse("graph missing \"nodes\" array".into())),
    };
    let ids_field = |item: &Json, key: &str| -> Result<Vec<usize>, DepyfError> {
        let arr = item
            .get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| DepyfError::Parse(format!("graph node missing array \"{}\"", key)))?;
        arr.iter()
            .map(|v| {
                v.as_f64()
                    .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                    .map(|n| n as usize)
                    .ok_or_else(|| DepyfError::Parse(format!("graph array \"{}\" holds a bad entry", key)))
            })
            .collect()
    };
    let mut g = Graph::new(name);
    for (id, item) in nodes.iter().enumerate() {
        let kind = item
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| DepyfError::Parse(format!("graph node {} missing \"kind\"", id)))?;
        let built = match kind {
            "placeholder" => {
                let pname = item
                    .get("pname")
                    .and_then(Json::as_str)
                    .ok_or_else(|| DepyfError::Parse(format!("placeholder {} missing \"pname\"", id)))?;
                let shape = ids_field(item, "shape")?;
                g.placeholder(pname, &shape)
            }
            "const_scalar" => {
                let bits = item
                    .get("bits")
                    .and_then(Json::as_str)
                    .ok_or_else(|| DepyfError::Parse(format!("const_scalar {} missing \"bits\"", id)))?;
                let v = u64::from_str_radix(bits, 16)
                    .map(f64::from_bits)
                    .map_err(|e| DepyfError::Parse(format!("bad const_scalar bits '{}': {}", bits, e)))?;
                g.const_scalar(v)
            }
            "const_tensor" => {
                let shape = ids_field(item, "shape")?;
                let hex = item
                    .get("data")
                    .and_then(Json::as_str)
                    .ok_or_else(|| DepyfError::Parse(format!("const_tensor {} missing \"data\"", id)))?;
                let data = f32s_from_hex(hex)?;
                if shape.iter().product::<usize>() != data.len() {
                    return Err(DepyfError::Parse(format!(
                        "const_tensor {} shape {:?} disagrees with {} data elements",
                        id,
                        shape,
                        data.len()
                    )));
                }
                g.const_tensor(Tensor::new(shape, data))
            }
            "op" => {
                let op = parse_op(item, id)?;
                let args = ids_field(item, "args")?;
                if args.iter().any(|&a| a >= id) {
                    return Err(DepyfError::Parse(format!(
                        "op node {} references a not-yet-defined arg ({:?})",
                        id, args
                    )));
                }
                let shape = ids_field(item, "shape")?;
                let built = g
                    .add_op(op, args)
                    .map_err(|e| DepyfError::Parse(format!("op node {} no longer infers: {}", id, e)))?;
                if g.nodes[built].shape != shape {
                    return Err(DepyfError::Parse(format!(
                        "op node {} shape drifted: recorded {:?}, inferred {:?}",
                        id, shape, g.nodes[built].shape
                    )));
                }
                built
            }
            other => return Err(DepyfError::Parse(format!("unknown graph node kind '{}'", other))),
        };
        if built != id {
            return Err(DepyfError::Parse(format!("graph node ids not dense at {}", id)));
        }
    }
    let inputs = ids_field(doc, "inputs")?;
    if inputs != g.inputs {
        return Err(DepyfError::Parse(format!(
            "graph inputs {:?} disagree with placeholder order {:?}",
            inputs, g.inputs
        )));
    }
    let outputs = ids_field(doc, "outputs")?;
    if let Some(&bad) = outputs.iter().find(|&&o| o >= g.nodes.len()) {
        return Err(DepyfError::Parse(format!("graph output {} out of range", bad)));
    }
    g.set_outputs(outputs);
    Ok(g)
}

fn parse_op(item: &Json, id: usize) -> Result<OpKind, DepyfError> {
    let name = item
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| DepyfError::Parse(format!("op node {} missing \"op\"", id)))?;
    let axis = |key: &str| -> Result<Option<usize>, DepyfError> {
        match item.get(key) {
            None | Some(Json::Null) => Ok(None),
            Some(v) => v
                .as_f64()
                .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                .map(|n| Some(n as usize))
                .ok_or_else(|| DepyfError::Parse(format!("op node {} has a bad \"{}\"", id, key))),
        }
    };
    Ok(match name {
        "add" => OpKind::Add,
        "sub" => OpKind::Sub,
        "mul" => OpKind::Mul,
        "div" => OpKind::Div,
        "pow" => OpKind::Pow,
        "maximum" => OpKind::Maximum,
        "minimum" => OpKind::Minimum,
        "neg" => OpKind::Neg,
        "relu" => OpKind::Relu,
        "gelu" => OpKind::Gelu,
        "tanh" => OpKind::Tanh,
        "sigmoid" => OpKind::Sigmoid,
        "exp" => OpKind::Exp,
        "log" => OpKind::Log,
        "sqrt" => OpKind::Sqrt,
        "abs" => OpKind::Abs,
        "matmul" => OpKind::MatMul,
        "t" => OpKind::Transpose,
        "softmax" => OpKind::Softmax,
        "layernorm" => OpKind::LayerNorm,
        "embedding" => OpKind::Embedding,
        "cross_entropy" => OpKind::CrossEntropy,
        "sum" => OpKind::Sum(axis("axis")?),
        "mean" => OpKind::Mean(axis("axis")?),
        "max" => OpKind::Max(axis("axis")?),
        "min" => OpKind::Min(axis("axis")?),
        "reshape" => {
            let arr = item
                .get("spec")
                .and_then(Json::as_arr)
                .ok_or_else(|| DepyfError::Parse(format!("reshape node {} missing \"spec\"", id)))?;
            let spec: Result<Vec<i64>, DepyfError> = arr
                .iter()
                .map(|v| {
                    v.as_f64()
                        .filter(|n| n.fract() == 0.0)
                        .map(|n| n as i64)
                        .ok_or_else(|| DepyfError::Parse(format!("reshape node {} has a bad spec", id)))
                })
                .collect();
            OpKind::Reshape(spec?)
        }
        "permute" => {
            let arr = item
                .get("perm")
                .and_then(Json::as_arr)
                .ok_or_else(|| DepyfError::Parse(format!("permute node {} missing \"perm\"", id)))?;
            let perm: Result<Vec<usize>, DepyfError> = arr
                .iter()
                .map(|v| {
                    v.as_f64()
                        .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                        .map(|n| n as usize)
                        .ok_or_else(|| DepyfError::Parse(format!("permute node {} has a bad perm", id)))
                })
                .collect();
            OpKind::Permute(perm?)
        }
        other => return Err(DepyfError::Parse(format!("unknown op kind '{}'", other))),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_graph() -> Graph {
        let mut g = Graph::new("__compiled_fn_1");
        let x = g.placeholder("x", &[2, 3]);
        let w = g.placeholder("w", &[3, 4]);
        let c = g.const_scalar(0.1);
        let ct = g.const_tensor(Tensor::new(vec![4], vec![-0.0, 1.5, f32::MIN_POSITIVE, 3.75]));
        let m = g.add_op(OpKind::MatMul, vec![x, w]).unwrap();
        let s = g.add_op(OpKind::Mul, vec![m, c]).unwrap();
        let a = g.add_op(OpKind::Add, vec![s, ct]).unwrap();
        let r = g.add_op(OpKind::Reshape(vec![-1, 2]), vec![a]).unwrap();
        let p = g.add_op(OpKind::Permute(vec![1, 0]), vec![r]).unwrap();
        let sm = g.add_op(OpKind::Sum(Some(1)), vec![p]).unwrap();
        let t = g.add_op(OpKind::Sum(None), vec![sm]).unwrap();
        g.set_outputs(vec![t, p]);
        g
    }

    #[test]
    fn f32_hex_round_trips_exotic_values() {
        let vals = vec![0.0f32, -0.0, 1.0, -1.5, f32::INFINITY, f32::NEG_INFINITY, f32::NAN, f32::MIN_POSITIVE];
        let back = f32s_from_hex(&f32s_to_hex(&vals)).unwrap();
        assert_eq!(back.len(), vals.len());
        for (a, b) in vals.iter().zip(back.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{} vs {}", a, b);
        }
        assert!(f32s_from_hex("3f8000").is_err(), "truncated payload must fail");
        assert!(f32s_from_hex("zzzzzzzz").is_err());
    }

    #[test]
    fn graph_round_trip_preserves_content_hash() {
        let g = sample_graph();
        let text = render_graph(&g);
        let back = parse_graph(&text).unwrap();
        assert_eq!(back.content_hash(), g.content_hash(), "round-trip must be bit-exact");
        assert_eq!(back.name, g.name);
        assert_eq!(back.inputs, g.inputs);
        assert_eq!(back.outputs, g.outputs);
        // And re-rendering is stable.
        assert_eq!(render_graph(&back), text);
    }

    #[test]
    fn every_op_kind_round_trips() {
        // Unary/binary/reduction coverage beyond the sample graph.
        let mut g = Graph::new("ops");
        let x = g.placeholder("x", &[2, 2]);
        let y = g.placeholder("y", &[2, 2]);
        let gamma = g.placeholder("gamma", &[2]);
        let beta = g.placeholder("beta", &[2]);
        let ids = g.placeholder("ids", &[3]);
        let logits = g.placeholder("logits", &[3, 2]);
        let tgt = g.placeholder("tgt", &[3]);
        let mut last = x;
        for op in [
            OpKind::Add,
            OpKind::Sub,
            OpKind::Mul,
            OpKind::Div,
            OpKind::Pow,
            OpKind::Maximum,
            OpKind::Minimum,
        ] {
            last = g.add_op(op, vec![last, y]).unwrap();
        }
        for op in [
            OpKind::Neg,
            OpKind::Relu,
            OpKind::Gelu,
            OpKind::Tanh,
            OpKind::Sigmoid,
            OpKind::Exp,
            OpKind::Log,
            OpKind::Sqrt,
            OpKind::Abs,
            OpKind::Softmax,
            OpKind::Transpose,
        ] {
            last = g.add_op(op, vec![last]).unwrap();
        }
        let mm = g.add_op(OpKind::MatMul, vec![last, y]).unwrap();
        let ln = g.add_op(OpKind::LayerNorm, vec![mm, gamma, beta]).unwrap();
        let mx = g.add_op(OpKind::Max(Some(0)), vec![ln]).unwrap();
        let mn = g.add_op(OpKind::Min(None), vec![mx]).unwrap();
        let me = g.add_op(OpKind::Mean(None), vec![mn]).unwrap();
        let emb = g.add_op(OpKind::Embedding, vec![y, ids]).unwrap();
        let ce = g.add_op(OpKind::CrossEntropy, vec![logits, tgt]).unwrap();
        g.set_outputs(vec![me, emb, ce]);
        let back = parse_graph(&render_graph(&g)).unwrap();
        assert_eq!(back.content_hash(), g.content_hash());
    }

    #[test]
    fn parse_rejects_corrupted_documents() {
        let text = render_graph(&sample_graph());
        assert!(parse_graph("").is_err());
        assert!(parse_graph("{}").is_err());
        assert!(parse_graph(&text.replace("\"schema_version\": 1", "\"schema_version\": 99")).is_err());
        // Unknown op.
        assert!(parse_graph(&text.replace("\"op\": \"matmul\"", "\"op\": \"conv3d\"")).is_err());
        // Recorded shape disagreeing with inference fails loudly.
        assert!(parse_graph(&text.replace("\"shape\": [2, 4]", "\"shape\": [4, 2]")).is_err());
        // Forward references are rejected.
        assert!(parse_graph(&text.replace("\"args\": [4, 2]", "\"args\": [4, 99]")).is_err());
        // Const payload size mismatch.
        let g2 = {
            let mut g = Graph::new("c");
            let t = g.const_tensor(Tensor::new(vec![2], vec![1.0, 2.0]));
            g.set_outputs(vec![t]);
            g
        };
        let bad = render_graph(&g2).replace("\"shape\": [2]", "\"shape\": [3]");
        assert!(parse_graph(&bad).is_err());
    }
}
