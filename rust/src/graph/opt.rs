//! `graph::opt` — the deterministic graph-optimizer pass pipeline that
//! runs between capture and lowering (at `Backend::plan` time, for every
//! backend).
//!
//! True to the paper, the transformation itself is transparent: the
//! optimizer returns pass-by-pass [`PassStat`]s, sessions dump the
//! optimized graph as `__optimized_*.{txt,json}` artifacts next to the
//! original, and compile plans record the pass list and per-pass node
//! deltas (`__plan_*.json`).
//!
//! ## Passes, in pipeline order
//!
//! 1. **`const_fold`** — op nodes whose inputs are all constants are
//!    evaluated with the eager executor's own
//!    [`eval_op`](crate::backend::eager::eval_op) (so folded values are
//!    bitwise what execution would have produced) and replaced by
//!    `ConstTensor` nodes. Outputs larger than [`FOLD_NUMEL_LIMIT`]
//!    elements are left unfolded so dumps and trace artifacts don't bloat.
//! 2. **`algebraic`** (`-O2` only) — identity rewrites: `x*1`, `1*x`,
//!    `x/1`, `x-0`, `x+0`, `x*0`, double-negation, `transpose∘transpose`,
//!    `reshape∘reshape` (collapsed to one reshape), identity permutes and
//!    same-shape reshapes; plus transpose hoisting over matmul
//!    (`transpose(a)·transpose(b)` → `transpose(b·a)`, one materialized
//!    transpose instead of two — gated on provably finite operands, see
//!    [`finite_elems`]).
//! 3. **`cse`** — common-subexpression elimination keyed on per-node
//!    structural hashes ([`Graph::node_structural_hash`]); structurally
//!    identical op/const nodes collapse to the first occurrence
//!    (placeholders are never merged — they are the calling convention).
//! 4. **`dce`** — dead-code elimination: op/const nodes unreachable from
//!    the outputs are dropped. Placeholders are always kept, dead or not,
//!    so the optimized graph accepts exactly the original input list.
//!
//! ## The bit-exactness contract
//!
//! Optimization must **never change results**: the conformance harness
//! replays every corpus graph at `--opt-level 0` vs `2` and demands
//! *bitwise* equality on the eager/sharded/batched backends. Every rewrite
//! above is therefore exact on IEEE f32 semantics, and the two classically
//! "value-safe but bit-unsafe" rewrites are gated:
//!
//! * `x + 0.0` is **not** an identity for `x = -0.0` (`-0.0 + 0.0 = +0.0`
//!   flips the sign bit). It fires only when the zero is all `-0.0` bits
//!   (`x + (-0.0) = x` holds for every f32) or when a small value
//!   analysis proves `x` never carries `-0.0` (outputs of
//!   `exp`/`sigmoid`/`softmax`/`abs`, sign-checked constants).
//! * `x * 0.0` is only `+0.0` when `x` is finite, non-NaN and
//!   non-negative (`-1.0 * 0.0 = -0.0`, `inf * 0.0 = NaN`,
//!   `NaN * 0.0 = NaN`). No op output can be proven NaN-free without
//!   input range analysis — even `sigmoid` propagates NaN — so this fires
//!   only for element-checked constants.
//!
//! `x - 0.0` and `x * 1.0` (and friends) are unconditionally bit-exact
//! and always fire. (Like every production compiler, rewrites that drop
//! an arithmetic op assume quiet-NaN payloads propagate through f32
//! `+`/`-`/`*` unchanged — true on the x86-64/aarch64 targets this crate
//! runs and tests on.)
//!
//! ## Where fusion lives
//!
//! Elementwise-chain **fusion is not a graph rewrite**: there is no
//! `OpKind::FusedElementwise` variant. The optimized graph contains only
//! the ordinary op kinds, so [`crate::graph::serde`] and
//! [`Graph::content_hash`] are untouched and trace bundles keep
//! round-tripping. Fusion happens *below* the IR, when the eager backend
//! builds its [`ExecPlan`](crate::backend::eager::ExecPlan): runs of
//! broadcasting-compatible elementwise ops become fused regions executed
//! as a single stride-walked loop (no intermediate tensor allocations).
//! The XLA backend lowers the unfused-but-folded graph and lets PJRT fuse.

use std::collections::HashMap;
use std::fmt;
use std::rc::Rc;
use std::sync::Arc;

use crate::tensor::Tensor;

use super::{Graph, NodeId, NodeKind, OpKind};

/// Optimization level (the CLI's `--opt-level 0|1|2`, default 2).
///
/// * `O0` — capture verbatim: no passes, no elementwise fusion.
/// * `O1` — cleanup only: `const_fold` + `cse` + `dce`.
/// * `O2` — `O1` plus `algebraic` rewrites, and the eager `ExecPlan`
///   fuses elementwise chains.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum OptLevel {
    O0,
    O1,
    #[default]
    O2,
}

impl OptLevel {
    /// Parse a CLI flag value (`"0"`, `"1"`, `"2"`).
    pub fn parse(s: &str) -> Option<OptLevel> {
        match s {
            "0" => Some(OptLevel::O0),
            "1" => Some(OptLevel::O1),
            "2" => Some(OptLevel::O2),
            _ => None,
        }
    }

    pub fn as_u8(self) -> u8 {
        match self {
            OptLevel::O0 => 0,
            OptLevel::O1 => 1,
            OptLevel::O2 => 2,
        }
    }

    pub fn from_u8(v: u8) -> Option<OptLevel> {
        match v {
            0 => Some(OptLevel::O0),
            1 => Some(OptLevel::O1),
            2 => Some(OptLevel::O2),
            _ => None,
        }
    }

    /// Whether the eager `ExecPlan` fuses elementwise chains at this level.
    pub fn fuses(self) -> bool {
        self >= OptLevel::O2
    }
}

impl fmt::Display for OptLevel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.as_u8())
    }
}

/// What one pass did: node counts around it plus how many rewrites fired
/// (folds, simplifications, merges, removals).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PassStat {
    pub pass: &'static str,
    pub nodes_before: usize,
    pub nodes_after: usize,
    pub rewrites: usize,
}

/// The optimizer's output: the (possibly shared, if nothing changed)
/// optimized graph plus per-pass statistics.
#[derive(Clone, Debug)]
pub struct Optimized {
    pub graph: Arc<Graph>,
    pub level: OptLevel,
    pub passes: Vec<PassStat>,
}

impl Optimized {
    /// True when any pass performed at least one rewrite.
    pub fn changed(&self) -> bool {
        self.passes.iter().any(|p| p.rewrites > 0)
    }

    /// Total rewrites across the pipeline.
    pub fn total_rewrites(&self) -> usize {
        self.passes.iter().map(|p| p.rewrites).sum()
    }
}

/// Folding cap: an op whose output has more elements than this stays
/// unfolded (a folded const is embedded in dumps, trace bundles and the
/// content hash — unbounded materialization would bloat all three).
pub const FOLD_NUMEL_LIMIT: usize = 4096;

/// Run the pass pipeline at `level`. `O0` returns the input graph
/// unchanged (shared `Rc`); so does any level whose passes all fire zero
/// rewrites, so `Arc::ptr_eq` distinguishes "optimized" from "verbatim".
pub fn optimize(graph: &Arc<Graph>, level: OptLevel) -> Optimized {
    if level == OptLevel::O0 {
        return Optimized { graph: Arc::clone(graph), level, passes: Vec::new() };
    }
    type Pass = fn(&Graph) -> (Graph, usize);
    let pipeline: &[(&'static str, Pass)] = match level {
        OptLevel::O0 => unreachable!(),
        OptLevel::O1 => &[("const_fold", const_fold), ("cse", cse), ("dce", dce)],
        OptLevel::O2 => {
            &[("const_fold", const_fold), ("algebraic", algebraic), ("cse", cse), ("dce", dce)]
        }
    };
    let mut g: Graph = (**graph).clone();
    let mut passes = Vec::with_capacity(pipeline.len());
    for &(name, pass) in pipeline {
        let nodes_before = g.nodes.len();
        let (next, rewrites) = pass(&g);
        passes.push(PassStat { pass: name, nodes_before, nodes_after: next.nodes.len(), rewrites });
        g = next;
    }
    let changed = passes.iter().any(|p| p.rewrites > 0);
    let graph = if changed { Arc::new(g) } else { Arc::clone(graph) };
    Optimized { graph, level, passes }
}

/// Render the optimizer report + optimized graph as a standalone JSON
/// document (the `__optimized_*.json` session artifact). The embedded
/// graph is the lossless [`super::serde`] encoding, so tooling can parse
/// it back bit-exactly and diff it against the original trace graph.
pub fn render_optimized_json(name: &str, opt: &Optimized) -> String {
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"name\": \"{}\",\n", crate::api::json::escape(name)));
    out.push_str(&format!("  \"level\": {},\n", opt.level.as_u8()));
    out.push_str("  \"passes\": [\n");
    for (i, p) in opt.passes.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"pass\": \"{}\", \"nodes_before\": {}, \"nodes_after\": {}, \"rewrites\": {}}}{}\n",
            p.pass,
            p.nodes_before,
            p.nodes_after,
            p.rewrites,
            if i + 1 < opt.passes.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n");
    out.push_str(&format!("  \"graph\": {}\n", super::serde::render_graph(&opt.graph)));
    out.push_str("}\n");
    out
}

// ---- rebuild plumbing ----

/// Copy a non-op node verbatim into `out`, returning its new id.
fn copy_leaf(out: &mut Graph, node: &super::Node) -> NodeId {
    match &node.kind {
        NodeKind::Placeholder { name } => out.placeholder(name, &node.shape),
        NodeKind::ConstScalar(v) => out.const_scalar(*v),
        NodeKind::ConstTensor(t) => out.const_tensor(t.clone()),
        NodeKind::Op(..) => unreachable!("copy_leaf on an op node"),
    }
}

/// Structural equality of two nodes in the same graph (consts by bit
/// pattern, ops by kind + args). Placeholders are never equal — each is a
/// distinct calling-convention slot.
fn nodes_equal(g: &Graph, a: NodeId, b: NodeId) -> bool {
    if g.nodes[a].shape != g.nodes[b].shape {
        return false;
    }
    match (&g.nodes[a].kind, &g.nodes[b].kind) {
        (NodeKind::ConstScalar(x), NodeKind::ConstScalar(y)) => x.to_bits() == y.to_bits(),
        (NodeKind::ConstTensor(x), NodeKind::ConstTensor(y)) => {
            x.shape() == y.shape()
                && x.data().iter().zip(y.data().iter()).all(|(p, q)| p.to_bits() == q.to_bits())
        }
        (NodeKind::Op(o1, a1), NodeKind::Op(o2, a2)) => o1 == o2 && a1 == a2,
        _ => false,
    }
}

// ---- pass: const_fold ----

/// Evaluate `op(margs)` against materialized constants using the eager
/// executor's own per-op evaluator: the node is appended to `out`,
/// evaluated, and popped again. Folded bits are exactly the bits
/// execution would have produced.
fn fold_eval(out: &mut Graph, op: &OpKind, margs: &[NodeId], env: &[Option<Tensor>]) -> Option<Tensor> {
    let id = out.add_op(op.clone(), margs.to_vec()).ok()?;
    let result = crate::backend::eager::eval_op(out, id, env).ok();
    out.nodes.pop();
    result
}

fn const_fold(g: &Graph) -> (Graph, usize) {
    let mut out = Graph::new(&g.name);
    let mut map = vec![0usize; g.nodes.len()];
    // Materialized constant per *new* node (None for placeholders/ops) —
    // exactly the env template the eager ExecPlan would build.
    let mut env: Vec<Option<Tensor>> = Vec::with_capacity(g.nodes.len());
    let mut rewrites = 0usize;
    for (id, node) in g.nodes.iter().enumerate() {
        map[id] = match &node.kind {
            NodeKind::Op(op, args) => {
                let margs: Vec<NodeId> = args.iter().map(|&a| map[a]).collect();
                let numel: usize = node.shape.iter().product();
                let foldable = numel <= FOLD_NUMEL_LIMIT && margs.iter().all(|&a| env[a].is_some());
                match foldable.then(|| fold_eval(&mut out, op, &margs, &env)).flatten() {
                    Some(value) => {
                        rewrites += 1;
                        env.push(Some(value.clone()));
                        out.const_tensor(value)
                    }
                    None => {
                        env.push(None);
                        out.add_op(op.clone(), margs).expect("shapes were already inferred")
                    }
                }
            }
            other => {
                env.push(match other {
                    NodeKind::ConstScalar(v) => Some(Tensor::scalar(*v as f32)),
                    NodeKind::ConstTensor(t) => Some(t.clone()),
                    _ => None,
                });
                copy_leaf(&mut out, node)
            }
        };
    }
    out.set_outputs(g.outputs.iter().map(|&o| map[o]).collect());
    (out, rewrites)
}

// ---- pass: algebraic ----

const ONE_BITS: u32 = 0x3f80_0000; // 1.0f32
const POS_ZERO_BITS: u32 = 0x0000_0000;
const NEG_ZERO_BITS: u32 = 0x8000_0000;

/// `Some(bits)` when the node is a constant whose every element shares one
/// bit pattern (empty tensors yield `None`).
fn const_fill_bits(g: &Graph, id: NodeId) -> Option<u32> {
    match &g.nodes[id].kind {
        NodeKind::ConstScalar(v) => Some((*v as f32).to_bits()),
        NodeKind::ConstTensor(t) => {
            let first = t.data().first()?.to_bits();
            t.data().iter().all(|x| x.to_bits() == first).then_some(first)
        }
        _ => None,
    }
}

/// Conservative: true when the node's value provably never contains a
/// `-0.0` element (so `x + 0.0 → x` is bit-exact).
fn never_negzero(g: &Graph, id: NodeId) -> bool {
    match &g.nodes[id].kind {
        NodeKind::Op(OpKind::Exp | OpKind::Sigmoid | OpKind::Softmax | OpKind::Abs, _) => true,
        NodeKind::ConstScalar(v) => (*v as f32).to_bits() != NEG_ZERO_BITS,
        NodeKind::ConstTensor(t) => t.data().iter().all(|x| x.to_bits() != NEG_ZERO_BITS),
        _ => false,
    }
}

/// Conservative: true when every element is provably finite, non-NaN and
/// non-negative with a positive sign bit (so `x * 0.0 → +0.0` is
/// bit-exact; `-1*0 = -0`, `inf*0 = NaN`, `NaN*0 = NaN` are the traps).
/// Only element-checked **constants** qualify: no op output can be proven
/// NaN-free without input range analysis (even `sigmoid` propagates NaN),
/// so in practice this arm fires for unfolded over-cap constants.
fn finite_nonneg(g: &Graph, id: NodeId) -> bool {
    match &g.nodes[id].kind {
        NodeKind::ConstScalar(v) => {
            let f = *v as f32;
            f.is_finite() && f.is_sign_positive()
        }
        NodeKind::ConstTensor(t) => t.data().iter().all(|x| x.is_finite() && x.is_sign_positive()),
        _ => false,
    }
}

/// Conservative: true when every element is provably finite and non-NaN
/// (sign unconstrained). As with [`finite_nonneg`], only element-checked
/// constants qualify — used to gate the transpose-hoisting matmul rewrite,
/// whose bit hazards (NaN-payload selection in a commuted multiply, the
/// kernel's skip-zero test moving between operands) all require a NaN or
/// an infinity to observe.
fn finite_elems(g: &Graph, id: NodeId) -> bool {
    match &g.nodes[id].kind {
        NodeKind::ConstScalar(v) => (*v as f32).is_finite(),
        NodeKind::ConstTensor(t) => t.data().iter().all(|x| x.is_finite()),
        _ => false,
    }
}

/// One algebraic rewrite decision.
enum Rewrite {
    /// Reuse an existing node (shape-identical by construction).
    Use(NodeId),
    /// Replace with a different (simpler) op.
    Op(OpKind, Vec<NodeId>),
    /// Replace with a constant.
    Const(Tensor),
    /// Replace with `outer(inner(args))` — the pass's only two-op rewrite
    /// (transpose hoisting emits a matmul *and* the hoisted transpose).
    Wrap(OpKind, Vec<NodeId>, OpKind),
}

/// Decide whether `op(margs)` (args already mapped into `out`) simplifies.
/// Every rewrite returned here is bit-exact on IEEE f32 semantics — see
/// the module docs for the `x+0` / `x*0` gating.
fn simplify(out: &Graph, op: &OpKind, margs: &[NodeId], shape: &[usize]) -> Option<Rewrite> {
    let arg_shape = |i: usize| out.nodes[margs[i]].shape.as_slice();
    match op {
        OpKind::Neg => match &out.nodes[margs[0]].kind {
            NodeKind::Op(OpKind::Neg, inner) => Some(Rewrite::Use(inner[0])),
            _ => None,
        },
        OpKind::Transpose => match &out.nodes[margs[0]].kind {
            NodeKind::Op(OpKind::Transpose, inner) => Some(Rewrite::Use(inner[0])),
            _ => None,
        },
        OpKind::Reshape(_) => {
            if arg_shape(0) == shape {
                return Some(Rewrite::Use(margs[0]));
            }
            match &out.nodes[margs[0]].kind {
                // reshape∘reshape: both only relabel the row-major layout,
                // so collapsing to one reshape with the final shape is
                // exact — and when that shape is the inner source's own,
                // the whole chain disappears.
                NodeKind::Op(OpKind::Reshape(_), inner) => {
                    if out.nodes[inner[0]].shape == shape {
                        Some(Rewrite::Use(inner[0]))
                    } else {
                        Some(Rewrite::Op(
                            OpKind::Reshape(shape.iter().map(|&d| d as i64).collect()),
                            vec![inner[0]],
                        ))
                    }
                }
                _ => None,
            }
        }
        OpKind::Permute(perm) => {
            perm.iter().enumerate().all(|(i, &p)| i == p).then(|| Rewrite::Use(margs[0]))
        }
        OpKind::Mul => {
            for (k, other) in [(0usize, 1usize), (1, 0)] {
                let Some(bits) = const_fill_bits(out, margs[k]) else { continue };
                if bits == ONE_BITS && arg_shape(other) == shape {
                    return Some(Rewrite::Use(margs[other]));
                }
                if bits == POS_ZERO_BITS && finite_nonneg(out, margs[other]) {
                    return Some(Rewrite::Const(Tensor::zeros(shape)));
                }
            }
            None
        }
        OpKind::Div => {
            (const_fill_bits(out, margs[1]) == Some(ONE_BITS) && arg_shape(0) == shape)
                .then(|| Rewrite::Use(margs[0]))
        }
        OpKind::Sub => {
            // x - (+0.0) = x for every f32 (including x = -0.0); x - (-0.0)
            // is NOT exact (-0 - -0 = +0), so only a +0 constant fires.
            (const_fill_bits(out, margs[1]) == Some(POS_ZERO_BITS) && arg_shape(0) == shape)
                .then(|| Rewrite::Use(margs[0]))
        }
        OpKind::Add => {
            for (k, other) in [(0usize, 1usize), (1, 0)] {
                let Some(bits) = const_fill_bits(out, margs[k]) else { continue };
                if arg_shape(other) != shape {
                    continue;
                }
                // x + (-0.0) = x for every f32; x + (+0.0) only when x is
                // provably free of -0.0 elements.
                if bits == NEG_ZERO_BITS
                    || (bits == POS_ZERO_BITS && never_negzero(out, margs[other]))
                {
                    return Some(Rewrite::Use(margs[other]));
                }
            }
            None
        }
        OpKind::MatMul => {
            // transpose(a)·transpose(b) → transpose(b·a): every output
            // element sums the same products over the same ascending-k
            // order, so the only bit hazards are the commuted multiply
            // (NaN-payload selection) and the kernel's skip-zero test
            // moving between operands (±0.0 absorption) — both need a NaN
            // or an infinity to observe, so the rewrite fires only when
            // both operands are element-checked finite ([`finite_elems`]).
            // Like `x*0`, in practice that means unfolded over-cap
            // constants (smaller const transposes fold away first).
            let (NodeKind::Op(OpKind::Transpose, ia), NodeKind::Op(OpKind::Transpose, ib)) =
                (&out.nodes[margs[0]].kind, &out.nodes[margs[1]].kind)
            else {
                return None;
            };
            let (a, b) = (ia[0], ib[0]);
            (out.nodes[a].shape.len() == 2
                && out.nodes[b].shape.len() == 2
                && finite_elems(out, a)
                && finite_elems(out, b))
                .then(|| Rewrite::Wrap(OpKind::MatMul, vec![b, a], OpKind::Transpose))
        }
        _ => None,
    }
}

fn algebraic(g: &Graph) -> (Graph, usize) {
    let mut out = Graph::new(&g.name);
    let mut map = vec![0usize; g.nodes.len()];
    let mut rewrites = 0usize;
    for (id, node) in g.nodes.iter().enumerate() {
        map[id] = match &node.kind {
            NodeKind::Op(op, args) => {
                let margs: Vec<NodeId> = args.iter().map(|&a| map[a]).collect();
                match simplify(&out, op, &margs, &node.shape) {
                    Some(Rewrite::Use(nid)) => {
                        rewrites += 1;
                        nid
                    }
                    Some(Rewrite::Op(new_op, new_args)) => {
                        rewrites += 1;
                        out.add_op(new_op, new_args).expect("rewrite preserves shapes")
                    }
                    Some(Rewrite::Const(t)) => {
                        rewrites += 1;
                        out.const_tensor(t)
                    }
                    Some(Rewrite::Wrap(inner_op, inner_args, outer_op)) => {
                        rewrites += 1;
                        let mid =
                            out.add_op(inner_op, inner_args).expect("rewrite preserves shapes");
                        out.add_op(outer_op, vec![mid]).expect("rewrite preserves shapes")
                    }
                    None => out.add_op(op.clone(), margs).expect("shapes were already inferred"),
                }
            }
            _ => copy_leaf(&mut out, node),
        };
    }
    out.set_outputs(g.outputs.iter().map(|&o| map[o]).collect());
    (out, rewrites)
}

// ---- pass: cse ----

fn cse(g: &Graph) -> (Graph, usize) {
    let mut out = Graph::new(&g.name);
    let mut map = vec![0usize; g.nodes.len()];
    let mut seen: HashMap<u64, Vec<NodeId>> = HashMap::new();
    let mut rewrites = 0usize;
    // Append the candidate node, then either keep it or pop it in favor of
    // a structurally identical earlier node.
    let mut dedupe = |out: &mut Graph, nid: NodeId, rewrites: &mut usize| -> NodeId {
        let key = out.node_structural_hash(nid);
        if let Some(cands) = seen.get(&key) {
            for &c in cands {
                if nodes_equal(out, c, nid) {
                    out.nodes.pop();
                    *rewrites += 1;
                    return c;
                }
            }
        }
        seen.entry(key).or_default().push(nid);
        nid
    };
    for (id, node) in g.nodes.iter().enumerate() {
        map[id] = match &node.kind {
            // Placeholders are the calling convention — never merged.
            NodeKind::Placeholder { name } => out.placeholder(name, &node.shape),
            NodeKind::ConstScalar(v) => {
                let nid = out.const_scalar(*v);
                dedupe(&mut out, nid, &mut rewrites)
            }
            NodeKind::ConstTensor(t) => {
                let nid = out.const_tensor(t.clone());
                dedupe(&mut out, nid, &mut rewrites)
            }
            NodeKind::Op(op, args) => {
                let margs: Vec<NodeId> = args.iter().map(|&a| map[a]).collect();
                let nid = out.add_op(op.clone(), margs).expect("shapes were already inferred");
                dedupe(&mut out, nid, &mut rewrites)
            }
        };
    }
    out.set_outputs(g.outputs.iter().map(|&o| map[o]).collect());
    (out, rewrites)
}

// ---- pass: dce ----

fn dce(g: &Graph) -> (Graph, usize) {
    let mut live = vec![false; g.nodes.len()];
    let mut stack: Vec<NodeId> = g.outputs.clone();
    while let Some(id) = stack.pop() {
        if live[id] {
            continue;
        }
        live[id] = true;
        if let NodeKind::Op(_, args) = &g.nodes[id].kind {
            stack.extend(args.iter().copied());
        }
    }
    let mut out = Graph::new(&g.name);
    let mut map = vec![usize::MAX; g.nodes.len()];
    let mut removed = 0usize;
    for (id, node) in g.nodes.iter().enumerate() {
        // Placeholders survive even when dead: the compiled fn is called
        // with the full original input list.
        if !live[id] && !matches!(node.kind, NodeKind::Placeholder { .. }) {
            removed += 1;
            continue;
        }
        map[id] = match &node.kind {
            NodeKind::Op(op, args) => {
                let margs: Vec<NodeId> = args.iter().map(|&a| map[a]).collect();
                out.add_op(op.clone(), margs).expect("shapes were already inferred")
            }
            _ => copy_leaf(&mut out, node),
        };
    }
    out.set_outputs(g.outputs.iter().map(|&o| map[o]).collect());
    (out, removed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::eager;
    use crate::tensor::Rng;

    fn run_both(g: &Arc<Graph>, level: OptLevel, seed: u64) -> (Vec<Tensor>, Vec<Tensor>) {
        let opt = optimize(g, level);
        let mut rng = Rng::new(seed);
        let inputs: Vec<Rc<Tensor>> = g
            .input_shapes()
            .into_iter()
            .map(|(_, s)| Rc::new(Tensor::randn(&s, &mut rng)))
            .collect();
        let want = eager::execute(g, &inputs).unwrap();
        let got = eager::execute(&opt.graph, &inputs).unwrap();
        (got, want)
    }

    fn assert_bitwise(g: &Arc<Graph>, level: OptLevel, seed: u64) {
        let (got, want) = run_both(g, level, seed);
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(want.iter()) {
            assert_eq!(a.shape(), b.shape());
            let eq = a.data().iter().zip(b.data().iter()).all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(eq, "optimizer changed bits: {:?} vs {:?}", a, b);
        }
    }

    #[test]
    fn level_parse_and_ordering() {
        assert_eq!(OptLevel::parse("0"), Some(OptLevel::O0));
        assert_eq!(OptLevel::parse("1"), Some(OptLevel::O1));
        assert_eq!(OptLevel::parse("2"), Some(OptLevel::O2));
        assert_eq!(OptLevel::parse("3"), None);
        assert!(OptLevel::O2 > OptLevel::O1);
        assert!(OptLevel::O2.fuses() && !OptLevel::O1.fuses());
        assert_eq!(OptLevel::default(), OptLevel::O2);
        assert_eq!(OptLevel::from_u8(2), Some(OptLevel::O2));
        assert_eq!(format!("{}", OptLevel::O1), "1");
    }

    #[test]
    fn o0_and_unchanged_graphs_share_the_input_rc() {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2, 3]);
        let w = g.placeholder("w", &[3, 4]);
        let m = g.add_op(OpKind::MatMul, vec![x, w]).unwrap();
        g.set_outputs(vec![m]);
        let g = Arc::new(g);
        let o0 = optimize(&g, OptLevel::O0);
        assert!(Arc::ptr_eq(&o0.graph, &g) && o0.passes.is_empty());
        // Nothing to do at O2 either: same Arc, zero-rewrite pass stats.
        let o2 = optimize(&g, OptLevel::O2);
        assert!(Arc::ptr_eq(&o2.graph, &g));
        assert!(!o2.changed());
        assert_eq!(o2.passes.len(), 4);
        assert_eq!(o2.passes[0].pass, "const_fold");
    }

    #[test]
    fn const_subtrees_fold_to_execution_bits() {
        // (2 + 3) * x + (ones[3] * 4).sqrt() — the const subtrees fold.
        let mut g = Graph::new("fold");
        let x = g.placeholder("x", &[3]);
        let c2 = g.const_scalar(2.0);
        let c3 = g.const_scalar(3.0);
        let c4 = g.const_scalar(4.0);
        let ones = g.const_tensor(Tensor::ones(&[3]));
        let s = g.add_op(OpKind::Add, vec![c2, c3]).unwrap();
        let sx = g.add_op(OpKind::Mul, vec![s, x]).unwrap();
        let o4 = g.add_op(OpKind::Mul, vec![ones, c4]).unwrap();
        let sq = g.add_op(OpKind::Sqrt, vec![o4]).unwrap();
        let out = g.add_op(OpKind::Add, vec![sx, sq]).unwrap();
        g.set_outputs(vec![out]);
        let g = Arc::new(g);
        let opt = optimize(&g, OptLevel::O1);
        assert!(opt.changed());
        // add(c2,c3), mul(ones,c4), sqrt fold; mul(s,x) and the final add stay.
        assert_eq!(opt.graph.num_ops(), 2, "{:?}", opt.graph);
        let folds = opt.passes.iter().find(|p| p.pass == "const_fold").unwrap();
        assert_eq!(folds.rewrites, 3);
        // DCE drops the now-dead original consts.
        assert!(opt.graph.nodes.len() < g.nodes.len());
        assert_bitwise(&g, OptLevel::O1, 7);
        assert_bitwise(&g, OptLevel::O2, 8);
    }

    #[test]
    fn fold_respects_the_numel_cap() {
        // An all-const op with an over-cap output must stay unfolded (its
        // consumers then stay too), while a small-output op over the same
        // big constant folds fine.
        let mut g = Graph::new("cap");
        let big = g.const_tensor(Tensor::ones(&[FOLD_NUMEL_LIMIT + 1]));
        let c = g.const_scalar(2.0);
        let m = g.add_op(OpKind::Mul, vec![big, c]).unwrap(); // output > cap
        let s = g.add_op(OpKind::Sum(None), vec![m]).unwrap(); // arg not const
        let s2 = g.add_op(OpKind::Sum(None), vec![big]).unwrap(); // scalar: folds
        let out = g.add_op(OpKind::Add, vec![s, s2]).unwrap();
        g.set_outputs(vec![out]);
        let g = Arc::new(g);
        let opt = optimize(&g, OptLevel::O1);
        let folds = opt.passes.iter().find(|p| p.pass == "const_fold").unwrap();
        assert_eq!(folds.rewrites, 1, "{:?}", opt.passes);
        assert!(opt
            .graph
            .nodes
            .iter()
            .any(|n| matches!(&n.kind, NodeKind::Op(OpKind::Mul, _))));
        assert_bitwise(&g, OptLevel::O1, 9);
    }

    #[test]
    fn cse_merges_structural_duplicates() {
        // relu(x)+relu(x) built twice over; CSE collapses the duplicates.
        let mut g = Graph::new("cse");
        let x = g.placeholder("x", &[4]);
        let r1 = g.add_op(OpKind::Relu, vec![x]).unwrap();
        let r2 = g.add_op(OpKind::Relu, vec![x]).unwrap();
        let a1 = g.add_op(OpKind::Add, vec![r1, r2]).unwrap();
        let r3 = g.add_op(OpKind::Relu, vec![x]).unwrap();
        let a2 = g.add_op(OpKind::Add, vec![r1, r3]).unwrap();
        let out = g.add_op(OpKind::Mul, vec![a1, a2]).unwrap();
        g.set_outputs(vec![out]);
        let g = Arc::new(g);
        let opt = optimize(&g, OptLevel::O1);
        // 3 relus -> 1, 2 structurally identical adds -> 1.
        assert_eq!(opt.graph.num_ops(), 3, "{:?}", opt.graph);
        assert_bitwise(&g, OptLevel::O1, 11);
    }

    #[test]
    fn cse_never_merges_placeholders_or_distinct_consts() {
        let mut g = Graph::new("ph");
        let x = g.placeholder("x", &[2]);
        let y = g.placeholder("y", &[2]); // same shape as x: must stay distinct
        let c1 = g.const_scalar(1.5);
        let c2 = g.const_scalar(2.5);
        let a = g.add_op(OpKind::Mul, vec![x, c1]).unwrap();
        let b = g.add_op(OpKind::Mul, vec![y, c2]).unwrap();
        let s = g.add_op(OpKind::Add, vec![a, b]).unwrap();
        g.set_outputs(vec![s]);
        let g = Arc::new(g);
        let opt = optimize(&g, OptLevel::O1);
        assert_eq!(opt.graph.inputs.len(), 2);
        assert_bitwise(&g, OptLevel::O1, 3);
    }

    #[test]
    fn dce_drops_dead_ops_but_keeps_placeholders() {
        let mut g = Graph::new("dce");
        let x = g.placeholder("x", &[3]);
        let unused_in = g.placeholder("unused", &[5]);
        let r = g.add_op(OpKind::Relu, vec![x]).unwrap();
        let _dead = g.add_op(OpKind::Exp, vec![x]).unwrap();
        let _dead2 = g.add_op(OpKind::Tanh, vec![unused_in]).unwrap();
        g.set_outputs(vec![r]);
        let g = Arc::new(g);
        let opt = optimize(&g, OptLevel::O1);
        assert_eq!(opt.graph.num_ops(), 1);
        // Both placeholders survive: the call arity is part of the contract.
        assert_eq!(opt.graph.inputs.len(), 2);
        assert_eq!(opt.graph.input_shapes(), g.input_shapes());
        assert_bitwise(&g, OptLevel::O1, 5);
    }

    #[test]
    fn algebraic_identities_fire_and_stay_bitwise() {
        // ((x * 1) / 1 - 0) double-neg, transpose∘transpose, reshape∘reshape.
        let mut g = Graph::new("alg");
        let x = g.placeholder("x", &[2, 6]);
        let one = g.const_scalar(1.0);
        let zero = g.const_scalar(0.0);
        let m = g.add_op(OpKind::Mul, vec![x, one]).unwrap();
        let d = g.add_op(OpKind::Div, vec![m, one]).unwrap();
        let s = g.add_op(OpKind::Sub, vec![d, zero]).unwrap();
        let n1 = g.add_op(OpKind::Neg, vec![s]).unwrap();
        let n2 = g.add_op(OpKind::Neg, vec![n1]).unwrap();
        let t1 = g.add_op(OpKind::Transpose, vec![n2]).unwrap();
        let t2 = g.add_op(OpKind::Transpose, vec![t1]).unwrap();
        let r1 = g.add_op(OpKind::Reshape(vec![3, -1]), vec![t2]).unwrap();
        let r2 = g.add_op(OpKind::Reshape(vec![-1, 6]), vec![r1]).unwrap();
        let out = g.add_op(OpKind::Sum(None), vec![r2]).unwrap();
        g.set_outputs(vec![out]);
        let g = Arc::new(g);
        let opt = optimize(&g, OptLevel::O2);
        // Everything between x and the sum cancels: reshape [2,6]->[2,6]
        // is itself erased by the same-shape rule, leaving just the sum.
        assert_eq!(opt.graph.num_ops(), 1, "{:?}", opt.graph);
        let alg = opt.passes.iter().find(|p| p.pass == "algebraic").unwrap();
        assert!(alg.rewrites >= 6, "{:?}", alg);
        assert_bitwise(&g, OptLevel::O2, 13);
        // O1 leaves algebraic identities alone.
        let o1 = optimize(&g, OptLevel::O1);
        assert!(o1.graph.num_ops() > 1);
    }

    #[test]
    fn transpose_hoisting_over_matmul() {
        // transpose(A)·transpose(B) over finite over-cap constants hoists
        // to transpose(B·A): one materialized transpose instead of two.
        let n = 65; // 65*65 = 4225 > FOLD_NUMEL_LIMIT: the consts stay unfolded
        let mut rng = Rng::new(0xACED);
        let mut g = Graph::new("th");
        let a = g.const_tensor(Tensor::randn(&[n, n], &mut rng));
        let b = g.const_tensor(Tensor::randn(&[n, n], &mut rng));
        let x = g.placeholder("x", &[1, n]);
        let ta = g.add_op(OpKind::Transpose, vec![a]).unwrap();
        let tb = g.add_op(OpKind::Transpose, vec![b]).unwrap();
        let m = g.add_op(OpKind::MatMul, vec![ta, tb]).unwrap();
        let y = g.add_op(OpKind::MatMul, vec![x, m]).unwrap();
        g.set_outputs(vec![y]);
        let g = Arc::new(g);
        let opt = optimize(&g, OptLevel::O2);
        let alg = opt.passes.iter().find(|p| p.pass == "algebraic").unwrap();
        assert!(alg.rewrites >= 1, "{:?}", opt.passes);
        let transposes = opt
            .graph
            .nodes
            .iter()
            .filter(|nd| matches!(&nd.kind, NodeKind::Op(OpKind::Transpose, _)))
            .count();
        assert_eq!(transposes, 1, "two transposes must hoist into one");
        assert_bitwise(&g, OptLevel::O2, 21);

        // The gate is real: placeholder operands can't be proven finite
        // (a NaN input would pick a different payload in the commuted
        // multiply), so the same shape must NOT rewrite.
        let mut g = Graph::new("th_gate");
        let p = g.placeholder("p", &[n, n]);
        let q = g.placeholder("q", &[n, n]);
        let tp = g.add_op(OpKind::Transpose, vec![p]).unwrap();
        let tq = g.add_op(OpKind::Transpose, vec![q]).unwrap();
        let m = g.add_op(OpKind::MatMul, vec![tp, tq]).unwrap();
        g.set_outputs(vec![m]);
        let opt = optimize(&Arc::new(g), OptLevel::O2);
        let transposes = opt
            .graph
            .nodes
            .iter()
            .filter(|nd| matches!(&nd.kind, NodeKind::Op(OpKind::Transpose, _)))
            .count();
        assert_eq!(transposes, 2, "unproven operands must keep both transposes");
    }

    #[test]
    fn signed_zero_gating_is_respected() {
        // exp(x) + 0 simplifies (exp never yields -0.0)...
        let mut g = Graph::new("zadd");
        let x = g.placeholder("x", &[3]);
        let zero = g.const_scalar(0.0);
        let e = g.add_op(OpKind::Exp, vec![x]).unwrap();
        let a = g.add_op(OpKind::Add, vec![e, zero]).unwrap();
        g.set_outputs(vec![a]);
        let opt = optimize(&Arc::new(g), OptLevel::O2);
        assert_eq!(opt.graph.num_ops(), 1, "exp(x)+0 must drop the add");

        // ...but a bare x + 0 must NOT (x = -0.0 would flip its sign bit).
        let mut g = Graph::new("zadd2");
        let x = g.placeholder("x", &[3]);
        let zero = g.const_scalar(0.0);
        let a = g.add_op(OpKind::Add, vec![x, zero]).unwrap();
        g.set_outputs(vec![a]);
        let g = Arc::new(g);
        let opt = optimize(&g, OptLevel::O2);
        assert_eq!(opt.graph.num_ops(), 1, "x+0 must survive: not bit-exact for -0.0");
        // The gate is real: -0.0 + 0.0 flips the sign bit.
        let neg0 = Rc::new(Tensor::new(vec![3], vec![-0.0, 1.0, -1.0]));
        let out = eager::execute(&g, &[neg0]).unwrap();
        assert_eq!(out[0].data()[0].to_bits(), 0.0f32.to_bits());

        // x + (-0.0) is exact for every x and always fires.
        let mut g = Graph::new("zadd3");
        let x = g.placeholder("x", &[3]);
        let nzero = g.const_scalar(-0.0);
        let a = g.add_op(OpKind::Add, vec![x, nzero]).unwrap();
        g.set_outputs(vec![a]);
        let opt = optimize(&Arc::new(g), OptLevel::O2);
        assert_eq!(opt.graph.num_ops(), 0, "x + (-0.0) is bit-exact for all x");

        // NO op output is provably NaN-free (sigmoid(NaN) = NaN and
        // NaN * 0 = NaN), so `u(x) * 0` never folds for any unary u...
        for op in [OpKind::Sigmoid, OpKind::Tanh] {
            let mut g = Graph::new("zmul");
            let x = g.placeholder("x", &[3]);
            let zero = g.const_scalar(0.0);
            let u = g.add_op(op, vec![x]).unwrap();
            let m = g.add_op(OpKind::Mul, vec![u, zero]).unwrap();
            g.set_outputs(vec![m]);
            let g = Arc::new(g);
            let opt = optimize(&g, OptLevel::O2);
            assert_eq!(opt.graph.num_ops(), 2, "op-output * 0 must survive (NaN/-0.0 inputs)");
            assert_bitwise(&g, OptLevel::O2, 17);
        }
        // ...but a checked positive-finite constant does — here an
        // over-cap const the folder left alone, erased by the x*0 rule.
        let mut g = Graph::new("zmul2");
        let big = g.const_tensor(Tensor::ones(&[FOLD_NUMEL_LIMIT + 1]));
        let zero = g.const_scalar(0.0);
        let m = g.add_op(OpKind::Mul, vec![big, zero]).unwrap();
        let s = g.add_op(OpKind::Sum(None), vec![m]).unwrap();
        g.set_outputs(vec![s]);
        let g = Arc::new(g);
        let opt = optimize(&g, OptLevel::O2);
        assert!(
            !opt.graph.nodes.iter().any(|n| matches!(&n.kind, NodeKind::Op(OpKind::Mul, _))),
            "positive-const * 0 folds to const zeros"
        );
        assert_bitwise(&g, OptLevel::O2, 18);
    }

    /// The x*0 gate is real: sigmoid propagates NaN, and folding to +0.0
    /// would change the answer for NaN inputs.
    #[test]
    fn mul_by_zero_gate_protects_nan_inputs() {
        let mut g = Graph::new("nan");
        let x = g.placeholder("x", &[2]);
        let zero = g.const_scalar(0.0);
        let u = g.add_op(OpKind::Sigmoid, vec![x]).unwrap();
        let m = g.add_op(OpKind::Mul, vec![u, zero]).unwrap();
        g.set_outputs(vec![m]);
        let g = Arc::new(g);
        let opt = optimize(&g, OptLevel::O2);
        let nan_in = Rc::new(Tensor::new(vec![2], vec![f32::NAN, 1.0]));
        let a = eager::execute(&g, &[Rc::clone(&nan_in)]).unwrap();
        let b = eager::execute(&opt.graph, &[nan_in]).unwrap();
        assert!(a[0].data()[0].is_nan(), "NaN must propagate through sigmoid*0");
        assert!(b[0].data()[0].is_nan(), "the optimizer must not erase the NaN");
        assert_eq!(a[0].data()[1].to_bits(), b[0].data()[1].to_bits());
    }

    #[test]
    fn optimized_graph_round_trips_through_serde() {
        // Satellite: fusion lives below serde; the optimizer emits only
        // ordinary node kinds, so its output graphs serialize losslessly.
        let mut g = Graph::new("rt");
        let x = g.placeholder("x", &[2, 3]);
        let c = g.const_scalar(2.0);
        let c2 = g.const_scalar(3.0);
        let s = g.add_op(OpKind::Add, vec![c, c2]).unwrap();
        let m = g.add_op(OpKind::Mul, vec![x, s]).unwrap();
        let r = g.add_op(OpKind::Relu, vec![m]).unwrap();
        g.set_outputs(vec![r]);
        let opt = optimize(&Arc::new(g), OptLevel::O2);
        assert!(opt.changed());
        let text = super::super::serde::render_graph(&opt.graph);
        let back = super::super::serde::parse_graph(&text).unwrap();
        assert_eq!(back.content_hash(), opt.graph.content_hash());
        // And the __optimized_*.json artifact parses as standard JSON.
        let doc = crate::api::json::parse(&render_optimized_json("rt", &opt)).unwrap();
        assert_eq!(doc.get("level").and_then(|v| v.as_f64()), Some(2.0));
        assert!(doc.get("graph").is_some());
        assert!(matches!(doc.get("passes"), Some(crate::api::json::Json::Arr(_))));
    }

    #[test]
    fn pipeline_is_bitwise_on_random_mixed_graphs() {
        // A handful of composite graphs: folding + cse + algebraic +
        // fusion-eligible chains, all bitwise-checked against the
        // unoptimized walk.
        let mut rng = Rng::new(0x0071);
        for case in 0..20 {
            let mut g = Graph::new(&format!("mix_{}", case));
            let x = g.placeholder("x", &[3, 4]);
            let b = g.placeholder("b", &[4]);
            let c1 = g.const_scalar((rng.uniform() as f64) * 2.0 + 0.5);
            let c2 = g.const_scalar(1.0);
            let cc = g.add_op(OpKind::Mul, vec![c1, c2]).unwrap(); // folds
            let t = g.add_op(OpKind::Mul, vec![x, cc]).unwrap();
            let t2 = g.add_op(OpKind::Add, vec![t, b]).unwrap();
            let a = g.add_op(OpKind::Gelu, vec![t2]).unwrap();
            let n1 = g.add_op(OpKind::Neg, vec![a]).unwrap();
            let n2 = g.add_op(OpKind::Neg, vec![n1]).unwrap(); // cancels
            let dup = g.add_op(OpKind::Gelu, vec![t2]).unwrap(); // CSE with a
            let s = g.add_op(OpKind::Add, vec![n2, dup]).unwrap();
            let out = g.add_op(OpKind::Sum(None), vec![s]).unwrap();
            g.set_outputs(vec![out]);
            let g = Arc::new(g);
            let opt = optimize(&g, OptLevel::O2);
            assert!(opt.changed(), "case {}", case);
            assert!(opt.graph.num_ops() < g.num_ops(), "case {}", case);
            assert_bitwise(&g, OptLevel::O1, 100 + case);
            assert_bitwise(&g, OptLevel::O2, 200 + case);
        }
    }
}
