//! [`TraceBundle`] — the versioned `__trace_*.json` artifact written by the
//! `recording` backend wrapper and consumed by `depyf replay`.
//!
//! A bundle is **self-contained**: it embeds a lossless serialization of
//! the compiled graph ([`crate::graph::serde`]), the guard descriptions of
//! the entry that was recorded, the module's compile stats, and every call
//! observed at runtime (input and output tensors with bit-exact f32
//! payloads). Replaying a bundle needs nothing but the bundle: the graph
//! is rebuilt, recompiled on any registered backend, and re-executed on
//! the recorded inputs; recorded outputs are the reference.

use std::path::Path;

use crate::api::json::{self, Json};
use crate::api::{DepyfError, ModuleStats};
use crate::graph::serde::{f32s_from_hex, f32s_to_hex, graph_from_value, render_graph};
use crate::graph::Graph;
use crate::tensor::Tensor;

/// Bumped whenever the trace JSON schema changes shape.
pub const TRACE_SCHEMA_VERSION: u64 = 1;

/// One recorded invocation of a compiled module.
#[derive(Clone, Debug)]
pub struct TraceCall {
    pub inputs: Vec<Tensor>,
    pub outputs: Vec<Tensor>,
    /// `backend_name` of the module that *actually* served this call when
    /// it differs from the bundle's backend — i.e. the call degraded to a
    /// fallback. `None` for calls served by the requested backend.
    /// Additive field: omitted from the JSON when `None`, defaulted when
    /// absent, so the schema version is unchanged.
    pub served_by: Option<String>,
}

/// A recorded compiled module: the graph, its compile context, and every
/// call the recording wrapper observed.
#[derive(Clone, Debug)]
pub struct TraceBundle {
    /// The compiled fn's name (`__compiled_fn_N` — N is the guard-entry
    /// id, which also disambiguates trace file names when two entries
    /// share a graph content hash).
    pub name: String,
    /// `backend_name` of the wrapped inner module that produced the
    /// recorded outputs.
    pub backend: String,
    /// `Graph::content_hash()` of `graph`.
    pub cache_key: u64,
    /// Guard descriptions of the entry this module was compiled for.
    pub guards: Vec<String>,
    /// The inner module's compile stats at record time.
    pub stats: ModuleStats,
    pub graph: Graph,
    pub calls: Vec<TraceCall>,
}

fn render_tensor(t: &Tensor) -> String {
    let dims: Vec<String> = t.shape().iter().map(|d| d.to_string()).collect();
    format!("{{\"shape\": [{}], \"data\": \"{}\"}}", dims.join(", "), f32s_to_hex(t.data()))
}

fn parse_tensor(v: &Json) -> Result<Tensor, DepyfError> {
    let shape_arr = v
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| DepyfError::Parse("trace tensor missing \"shape\"".into()))?;
    let shape: Result<Vec<usize>, DepyfError> = shape_arr
        .iter()
        .map(|d| {
            d.as_f64()
                .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                .map(|n| n as usize)
                .ok_or_else(|| DepyfError::Parse("trace tensor has a bad shape entry".into()))
        })
        .collect();
    let shape = shape?;
    let data = f32s_from_hex(
        v.get("data")
            .and_then(Json::as_str)
            .ok_or_else(|| DepyfError::Parse("trace tensor missing \"data\"".into()))?,
    )?;
    if shape.iter().product::<usize>() != data.len() {
        return Err(DepyfError::Parse(format!(
            "trace tensor shape {:?} disagrees with {} data elements",
            shape,
            data.len()
        )));
    }
    Ok(Tensor::new(shape, data))
}

impl TraceBundle {
    /// Render the bundle as its `__trace_*.json` document.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"schema_version\": {},\n", TRACE_SCHEMA_VERSION));
        out.push_str(&format!("  \"name\": \"{}\",\n", json::escape(&self.name)));
        out.push_str(&format!("  \"backend\": \"{}\",\n", json::escape(&self.backend)));
        out.push_str(&format!("  \"cache_key\": \"{:016x}\",\n", self.cache_key));
        let guards: Vec<String> =
            self.guards.iter().map(|g| format!("\"{}\"", json::escape(g))).collect();
        out.push_str(&format!("  \"guards\": [{}],\n", guards.join(", ")));
        out.push_str(&format!(
            "  \"stats\": {{\"partitions\": {}, \"bucket\": {}, \"cache_hits\": {}}},\n",
            self.stats.partitions,
            self.stats.bucket.map(|b| b.to_string()).unwrap_or_else(|| "null".into()),
            self.stats.cache_hits
        ));
        // The embedded graph document (2-space indented block).
        let graph_text = render_graph(&self.graph);
        let indented: Vec<&str> = graph_text.lines().collect();
        out.push_str("  \"graph\": ");
        for (i, line) in indented.iter().enumerate() {
            if i > 0 {
                out.push_str("  ");
            }
            out.push_str(line);
            if i + 1 < indented.len() {
                out.push('\n');
            }
        }
        out.push_str(",\n");
        out.push_str("  \"calls\": [\n");
        for (i, call) in self.calls.iter().enumerate() {
            let ins: Vec<String> = call.inputs.iter().map(render_tensor).collect();
            let outs: Vec<String> = call.outputs.iter().map(render_tensor).collect();
            let served = match &call.served_by {
                Some(b) => format!(", \"served_by\": \"{}\"", json::escape(b)),
                None => String::new(),
            };
            out.push_str(&format!(
                "    {{\"inputs\": [{}], \"outputs\": [{}]{}}}{}\n",
                ins.join(", "),
                outs.join(", "),
                served,
                if i + 1 < self.calls.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Parse a trace document (inverse of [`TraceBundle::to_json`]).
    pub fn parse(text: &str) -> Result<TraceBundle, DepyfError> {
        let doc = json::parse(text)?;
        match doc.get("schema_version") {
            Some(Json::Num(v)) if *v == TRACE_SCHEMA_VERSION as f64 => {}
            Some(Json::Num(v)) => {
                return Err(DepyfError::Parse(format!(
                    "unsupported trace schema_version {} (expected {})",
                    v, TRACE_SCHEMA_VERSION
                )))
            }
            _ => return Err(DepyfError::Parse("trace missing \"schema_version\"".into())),
        }
        let str_field = |key: &str| -> Result<String, DepyfError> {
            doc.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| DepyfError::Parse(format!("trace missing string \"{}\"", key)))
        };
        let name = str_field("name")?;
        let backend = str_field("backend")?;
        let cache_key_text = str_field("cache_key")?;
        let cache_key = u64::from_str_radix(&cache_key_text, 16)
            .map_err(|e| DepyfError::Parse(format!("bad trace cache key '{}': {}", cache_key_text, e)))?;
        let guards = match doc.get("guards") {
            Some(Json::Arr(items)) => items
                .iter()
                .map(|v| {
                    v.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| DepyfError::Parse("trace guard is not a string".into()))
                })
                .collect::<Result<Vec<String>, DepyfError>>()?,
            _ => return Err(DepyfError::Parse("trace missing \"guards\" array".into())),
        };
        let stats_obj = doc
            .get("stats")
            .ok_or_else(|| DepyfError::Parse("trace missing \"stats\"".into()))?;
        let stat_num = |key: &str| -> Result<u64, DepyfError> {
            stats_obj
                .get(key)
                .and_then(Json::as_f64)
                .map(|v| v as u64)
                .ok_or_else(|| DepyfError::Parse(format!("trace stats missing \"{}\"", key)))
        };
        let stats = ModuleStats {
            partitions: stat_num("partitions")?,
            bucket: match stats_obj.get("bucket") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_f64().map(|b| b as u64).ok_or_else(|| {
                    DepyfError::Parse("trace stats has a non-numeric \"bucket\"".into())
                })?),
            },
            cache_hits: stat_num("cache_hits")?,
        };
        let graph = graph_from_value(
            doc.get("graph")
                .ok_or_else(|| DepyfError::Parse("trace missing \"graph\"".into()))?,
        )?;
        if graph.content_hash() != cache_key {
            return Err(DepyfError::Parse(format!(
                "trace cache_key {:016x} disagrees with embedded graph hash {:016x}",
                cache_key,
                graph.content_hash()
            )));
        }
        let calls_arr = match doc.get("calls") {
            Some(Json::Arr(items)) => items,
            _ => return Err(DepyfError::Parse("trace missing \"calls\" array".into())),
        };
        let mut calls = Vec::with_capacity(calls_arr.len());
        for item in calls_arr {
            let tensor_list = |key: &str| -> Result<Vec<Tensor>, DepyfError> {
                item.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| DepyfError::Parse(format!("trace call missing \"{}\"", key)))?
                    .iter()
                    .map(parse_tensor)
                    .collect()
            };
            calls.push(TraceCall {
                inputs: tensor_list("inputs")?,
                outputs: tensor_list("outputs")?,
                served_by: item.get("served_by").and_then(Json::as_str).map(str::to_string),
            });
        }
        Ok(TraceBundle { name, backend, cache_key, guards, stats, graph, calls })
    }

    /// Read + parse a trace bundle from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<TraceBundle, DepyfError> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| DepyfError::io(format!("read {}", path.display()), e))?;
        TraceBundle::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    fn sample() -> TraceBundle {
        let mut g = Graph::new("__compiled_fn_3");
        let x = g.placeholder("x", &[2, 2]);
        let c = g.const_scalar(2.0);
        let m = g.add_op(OpKind::Mul, vec![x, c]).unwrap();
        let r = g.add_op(OpKind::Relu, vec![m]).unwrap();
        g.set_outputs(vec![r]);
        let cache_key = g.content_hash();
        TraceBundle {
            name: "__compiled_fn_3".into(),
            backend: "eager".into(),
            cache_key,
            guards: vec!["check_tensor(args[0], shape=[2, 2])".into(), "k == 2".into()],
            stats: ModuleStats { partitions: 2, bucket: Some(8), cache_hits: 1 },
            graph: g,
            calls: vec![
                TraceCall {
                    inputs: vec![Tensor::new(vec![2, 2], vec![-1.0, 2.0, -0.0, f32::NAN])],
                    outputs: vec![Tensor::new(vec![2, 2], vec![0.0, 4.0, 0.0, f32::NAN])],
                    served_by: None,
                },
                TraceCall {
                    inputs: vec![Tensor::new(vec![2, 2], vec![1.0, 1.0, 1.0, 1.0])],
                    outputs: vec![Tensor::new(vec![2, 2], vec![2.0, 2.0, 2.0, 2.0])],
                    served_by: Some("eager (xla call fallback)".into()),
                },
            ],
        }
    }

    fn bits(t: &Tensor) -> Vec<u32> {
        t.data().iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn bundle_round_trips_bit_exactly() {
        let b = sample();
        let text = b.to_json();
        let back = TraceBundle::parse(&text).unwrap();
        assert_eq!(back.name, b.name);
        assert_eq!(back.backend, b.backend);
        assert_eq!(back.cache_key, b.cache_key);
        assert_eq!(back.guards, b.guards);
        assert_eq!(back.stats, b.stats);
        assert_eq!(back.graph.content_hash(), b.graph.content_hash());
        assert_eq!(back.calls.len(), 2);
        for (a, bb) in back.calls.iter().zip(b.calls.iter()) {
            for (ta, tb) in a.inputs.iter().zip(bb.inputs.iter()) {
                assert_eq!(ta.shape(), tb.shape());
                assert_eq!(bits(ta), bits(tb), "NaN/-0.0 payloads must survive");
            }
            for (ta, tb) in a.outputs.iter().zip(bb.outputs.iter()) {
                assert_eq!(bits(ta), bits(tb));
            }
        }
        // served_by is per-call: absent stays None, recorded value survives.
        assert_eq!(back.calls[0].served_by, None);
        assert_eq!(back.calls[1].served_by.as_deref(), Some("eager (xla call fallback)"));
        // Re-render is stable.
        assert_eq!(back.to_json(), text);
    }

    #[test]
    fn parse_rejects_bad_documents() {
        let text = sample().to_json();
        assert!(TraceBundle::parse("").is_err());
        assert!(TraceBundle::parse("{}").is_err());
        assert!(TraceBundle::parse(&text.replace("\"schema_version\": 1", "\"schema_version\": 7")).is_err());
        // Tampered graph: embedded hash check trips.
        assert!(TraceBundle::parse(&text.replace("\"op\": \"relu\"", "\"op\": \"tanh\"")).is_err());
        // Truncated tensor payload.
        let b = sample();
        let hex = f32s_to_hex(&b.calls[0].inputs[0].data()[..1]);
        let full = f32s_to_hex(b.calls[0].inputs[0].data());
        assert!(TraceBundle::parse(&text.replacen(&full, &hex, 1)).is_err());
    }

    #[test]
    fn load_reads_from_disk() {
        let dir = std::env::temp_dir().join(format!("depyf_trace_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("__trace_test.json");
        let b = sample();
        std::fs::write(&path, b.to_json()).unwrap();
        let back = TraceBundle::load(&path).unwrap();
        assert_eq!(back.cache_key, b.cache_key);
        assert!(TraceBundle::load(dir.join("missing.json")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
