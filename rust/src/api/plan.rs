//! [`CompilePlan`] — the declarative output of [`crate::api::Backend::plan`].
//!
//! A plan says *what* a backend decided before anything is built: how the
//! graph is partitioned (node ranges, per-partition target and cache key)
//! and whether/how the dynamic leading dim is padded into a bucket. Plans
//! render to JSON (`__plan_<graph>.json` dump artifacts, indexed in
//! `manifest.json`) and parse back losslessly, so external tooling can
//! inspect partitioning decisions the same way it inspects guards.

use crate::api::json::{self, Json};
use crate::graph::opt::Optimized;

use super::backend::CompileRequest;
use super::error::DepyfError;

/// Bumped whenever the plan JSON schema changes shape.
pub const PLAN_SCHEMA_VERSION: u64 = 1;

/// One optimizer pass's node delta, as recorded in the plan JSON.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PassDelta {
    pub pass: String,
    pub nodes_before: usize,
    pub nodes_after: usize,
    pub rewrites: usize,
}

/// The optimizer decisions baked into a plan: the level that ran and the
/// pass list with per-pass node deltas (`"opt"` in `__plan_*.json`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct OptSummary {
    pub level: u8,
    pub passes: Vec<PassDelta>,
}

impl OptSummary {
    pub fn from_optimized(opt: &Optimized) -> OptSummary {
        OptSummary {
            level: opt.level.as_u8(),
            passes: opt
                .passes
                .iter()
                .map(|p| PassDelta {
                    pass: p.pass.to_string(),
                    nodes_before: p.nodes_before,
                    nodes_after: p.nodes_after,
                    rewrites: p.rewrites,
                })
                .collect(),
        }
    }
}

/// One partition of a captured graph: which op nodes it owns, which
/// original-graph values it consumes/produces, and where it compiles to.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PartitionPlan {
    pub index: usize,
    /// Lowering target for this partition (`"xla"` or `"eager"`).
    pub target: String,
    /// Op node ids (in the original graph) executed by this partition.
    pub nodes: Vec<usize>,
    /// Original-graph node ids this partition reads (placeholders and
    /// earlier partitions' outputs; replicated constants excluded).
    pub inputs: Vec<usize>,
    /// Original-graph node ids this partition produces for later
    /// partitions or the final outputs.
    pub outputs: Vec<usize>,
    /// `content_hash` of the extracted partition subgraph — the compile
    /// cache key this partition's executable is stored under.
    pub cache_key: u64,
}

/// A padding/bucketing decision over the dynamic leading dim.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchPlan {
    /// The padded axis (always 0 today — the leading dim).
    pub dim: usize,
    /// The captured (guard-pinned) batch size.
    pub orig: usize,
    /// The padded bucket size (next power of two ≥ `orig`); every guard
    /// entry whose batch lands in the same bucket shares one executable.
    pub bucket: usize,
    /// Input positions (into `graph.inputs`) padded at call time.
    pub padded_inputs: Vec<usize>,
    /// Output positions sliced back to `orig` rows after execution.
    pub sliced_outputs: Vec<usize>,
}

/// The declarative compile plan: what [`crate::api::Backend::lower`] will
/// build, as data.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CompilePlan {
    /// The backend that produced the plan.
    pub backend: String,
    /// The graph name the plan applies to.
    pub graph: String,
    /// The whole-graph content hash (the request's cache key).
    pub cache_key: u64,
    pub partitions: Vec<PartitionPlan>,
    /// Present when the backend pads/buckets the leading dim.
    pub batch: Option<BatchPlan>,
    /// The optimizer run that produced the planned graph (level + pass
    /// deltas); `None` for plans written before the optimizer existed.
    pub opt: Option<OptSummary>,
}

impl CompilePlan {
    /// The trivial single-partition plan every monolithic backend uses:
    /// all ops in one partition, lowered to `target`. Node ids refer to
    /// the **optimized** graph (`req.optimized()`), and the partition's
    /// cache key is the optimized graph's content hash — so equivalent
    /// captures share executables.
    pub fn monolithic(backend: &str, req: &CompileRequest, target: &str) -> CompilePlan {
        let opt = req.optimized();
        let g = &opt.graph;
        let nodes: Vec<usize> = g
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| matches!(n.kind, crate::graph::NodeKind::Op(..)))
            .map(|(id, _)| id)
            .collect();
        CompilePlan {
            backend: backend.to_string(),
            graph: g.name.clone(),
            cache_key: req.cache_key,
            partitions: vec![PartitionPlan {
                index: 0,
                target: target.to_string(),
                nodes,
                inputs: g.inputs.clone(),
                outputs: g.outputs.clone(),
                cache_key: g.content_hash(),
            }],
            batch: None,
            opt: Some(OptSummary::from_optimized(&opt)),
        }
    }

    /// Render the plan as a JSON document (the `__plan_*.json` artifact).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"schema_version\": {},\n", PLAN_SCHEMA_VERSION));
        out.push_str(&format!("  \"backend\": \"{}\",\n", json::escape(&self.backend)));
        out.push_str(&format!("  \"graph\": \"{}\",\n", json::escape(&self.graph)));
        out.push_str(&format!("  \"cache_key\": \"{:016x}\",\n", self.cache_key));
        out.push_str("  \"partitions\": [\n");
        for (i, p) in self.partitions.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"index\": {}, \"target\": \"{}\", \"cache_key\": \"{:016x}\", \"nodes\": {}, \"inputs\": {}, \"outputs\": {}}}{}\n",
                p.index,
                json::escape(&p.target),
                p.cache_key,
                render_ids(&p.nodes),
                render_ids(&p.inputs),
                render_ids(&p.outputs),
                if i + 1 < self.partitions.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]");
        if let Some(o) = &self.opt {
            out.push_str(&format!(",\n  \"opt\": {{\"level\": {}, \"passes\": [", o.level));
            for (i, p) in o.passes.iter().enumerate() {
                out.push_str(&format!(
                    "{}{{\"pass\": \"{}\", \"nodes_before\": {}, \"nodes_after\": {}, \"rewrites\": {}}}",
                    if i > 0 { ", " } else { "" },
                    json::escape(&p.pass),
                    p.nodes_before,
                    p.nodes_after,
                    p.rewrites
                ));
            }
            out.push_str("]}");
        }
        if let Some(b) = &self.batch {
            out.push_str(&format!(
                ",\n  \"batch\": {{\"dim\": {}, \"orig\": {}, \"bucket\": {}, \"padded_inputs\": {}, \"sliced_outputs\": {}}}\n",
                b.dim,
                b.orig,
                b.bucket,
                render_ids(&b.padded_inputs),
                render_ids(&b.sliced_outputs)
            ));
        } else {
            out.push('\n');
        }
        out.push_str("}\n");
        out
    }

    /// Parse a plan document (inverse of [`CompilePlan::to_json`]).
    pub fn parse(text: &str) -> Result<CompilePlan, DepyfError> {
        let doc = json::parse(text)?;
        if let Some(Json::Num(v)) = doc.get("schema_version") {
            if *v != PLAN_SCHEMA_VERSION as f64 {
                return Err(DepyfError::Parse(format!(
                    "unsupported plan schema_version {} (expected {})",
                    v, PLAN_SCHEMA_VERSION
                )));
            }
        }
        let str_field = |item: &Json, key: &str| -> Result<String, DepyfError> {
            item.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| DepyfError::Parse(format!("plan missing string \"{}\"", key)))
        };
        let num_field = |item: &Json, key: &str| -> Result<usize, DepyfError> {
            item.get(key)
                .and_then(Json::as_f64)
                .map(|v| v as usize)
                .ok_or_else(|| DepyfError::Parse(format!("plan missing number \"{}\"", key)))
        };
        let key_field = |item: &Json, key: &str| -> Result<u64, DepyfError> {
            let s = str_field(item, key)?;
            u64::from_str_radix(&s, 16)
                .map_err(|e| DepyfError::Parse(format!("bad cache key '{}': {}", s, e)))
        };
        let ids_field = |item: &Json, key: &str| -> Result<Vec<usize>, DepyfError> {
            let arr = item
                .get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| DepyfError::Parse(format!("plan missing array \"{}\"", key)))?;
            arr.iter()
                .map(|v| {
                    v.as_f64().map(|n| n as usize).ok_or_else(|| {
                        DepyfError::Parse(format!("plan array \"{}\" holds a non-numeric entry", key))
                    })
                })
                .collect()
        };
        let parts = match doc.get("partitions") {
            Some(Json::Arr(items)) => items,
            _ => return Err(DepyfError::Parse("plan missing \"partitions\" array".into())),
        };
        let mut partitions = Vec::with_capacity(parts.len());
        for item in parts {
            partitions.push(PartitionPlan {
                index: num_field(item, "index")?,
                target: str_field(item, "target")?,
                cache_key: key_field(item, "cache_key")?,
                nodes: ids_field(item, "nodes")?,
                inputs: ids_field(item, "inputs")?,
                outputs: ids_field(item, "outputs")?,
            });
        }
        let batch = match doc.get("batch") {
            None | Some(Json::Null) => None,
            Some(b) => Some(BatchPlan {
                dim: num_field(b, "dim")?,
                orig: num_field(b, "orig")?,
                bucket: num_field(b, "bucket")?,
                padded_inputs: ids_field(b, "padded_inputs")?,
                sliced_outputs: ids_field(b, "sliced_outputs")?,
            }),
        };
        let opt = match doc.get("opt") {
            None | Some(Json::Null) => None,
            Some(o) => {
                let passes = match o.get("passes") {
                    Some(Json::Arr(items)) => items
                        .iter()
                        .map(|item| {
                            Ok(PassDelta {
                                pass: str_field(item, "pass")?,
                                nodes_before: num_field(item, "nodes_before")?,
                                nodes_after: num_field(item, "nodes_after")?,
                                rewrites: num_field(item, "rewrites")?,
                            })
                        })
                        .collect::<Result<Vec<PassDelta>, DepyfError>>()?,
                    _ => return Err(DepyfError::Parse("plan \"opt\" missing \"passes\" array".into())),
                };
                Some(OptSummary { level: num_field(o, "level")? as u8, passes })
            }
        };
        Ok(CompilePlan {
            backend: str_field(&doc, "backend")?,
            graph: str_field(&doc, "graph")?,
            cache_key: key_field(&doc, "cache_key")?,
            partitions,
            batch,
            opt,
        })
    }
}

fn render_ids(ids: &[usize]) -> String {
    let inner: Vec<String> = ids.iter().map(|i| i.to_string()).collect();
    format!("[{}]", inner.join(", "))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CompilePlan {
        CompilePlan {
            backend: "sharded".into(),
            graph: "__compiled_fn_1".into(),
            cache_key: 0xDEAD_BEEF_0BAD_F00D,
            partitions: vec![
                PartitionPlan {
                    index: 0,
                    target: "xla".into(),
                    nodes: vec![2, 3],
                    inputs: vec![0, 1],
                    outputs: vec![3],
                    cache_key: 0x0123_4567_89AB_CDEF,
                },
                PartitionPlan {
                    index: 1,
                    target: "eager".into(),
                    nodes: vec![4],
                    inputs: vec![3],
                    outputs: vec![4],
                    cache_key: u64::MAX,
                },
            ],
            batch: Some(BatchPlan {
                dim: 0,
                orig: 5,
                bucket: 8,
                padded_inputs: vec![0],
                sliced_outputs: vec![0],
            }),
            opt: Some(OptSummary {
                level: 2,
                passes: vec![
                    PassDelta { pass: "const_fold".into(), nodes_before: 9, nodes_after: 9, rewrites: 2 },
                    PassDelta { pass: "dce".into(), nodes_before: 9, nodes_after: 7, rewrites: 2 },
                ],
            }),
        }
    }

    #[test]
    fn plan_json_round_trips() {
        let plan = sample();
        let text = plan.to_json();
        let back = CompilePlan::parse(&text).unwrap();
        assert_eq!(back, plan);
        // u64 cache keys survive (they are hex strings, not f64 numbers).
        assert_eq!(back.partitions[1].cache_key, u64::MAX);
        // The opt summary round-trips pass-for-pass.
        let opt = back.opt.unwrap();
        assert_eq!(opt.level, 2);
        assert_eq!(opt.passes[1].nodes_after, 7);
    }

    #[test]
    fn batchless_plan_round_trips() {
        let mut plan = sample();
        plan.batch = None;
        let back = CompilePlan::parse(&plan.to_json()).unwrap();
        assert_eq!(back, plan);
    }

    #[test]
    fn optless_plan_round_trips() {
        // Plans written before the optimizer existed (no "opt" key) still
        // parse; the field stays None and re-renders without the key.
        let mut plan = sample();
        plan.opt = None;
        let text = plan.to_json();
        assert!(!text.contains("\"opt\""));
        let back = CompilePlan::parse(&text).unwrap();
        assert_eq!(back, plan);
    }

    /// Satellite: every way the *opt summary* can be malformed is a loud
    /// parse error, not a silently-defaulted field.
    #[test]
    fn parse_rejects_malformed_opt_summaries() {
        let good = sample().to_json();
        assert!(CompilePlan::parse(&good).is_ok());
        let surgeries: &[(&str, &str, &str)] = &[
            ("opt missing level", "\"level\": 2, ", ""),
            ("opt level is a string", "\"level\": 2", "\"level\": \"two\""),
            ("opt passes not an array", "\"passes\": [{", "\"passes\": 5, \"unused\": [{"),
            ("pass missing name", "\"pass\": \"const_fold\", ", ""),
            ("pass name is a number", "\"pass\": \"const_fold\"", "\"pass\": 3"),
            ("pass missing nodes_before", "\"nodes_before\": 9, \"nodes_after\": 9", "\"nodes_after\": 9"),
            ("pass rewrites is a string", "\"rewrites\": 2}", "\"rewrites\": \"2\"}"),
        ];
        for (why, needle, replacement) in surgeries {
            let mutated = good.replacen(needle, replacement, 1);
            assert_ne!(mutated, good, "surgery '{}' did not apply", why);
            assert!(CompilePlan::parse(&mutated).is_err(), "accepted malformed plan: {}", why);
        }
        // A null opt is the explicit "no optimizer ran" encoding.
        let nulled = good.replace(
            "\"opt\": {\"level\": 2, \"passes\": [{\"pass\": \"const_fold\", \"nodes_before\": 9, \"nodes_after\": 9, \"rewrites\": 2}, {\"pass\": \"dce\", \"nodes_before\": 9, \"nodes_after\": 7, \"rewrites\": 2}]}",
            "\"opt\": null",
        );
        assert_ne!(nulled, good);
        assert_eq!(CompilePlan::parse(&nulled).unwrap().opt, None);
    }

    #[test]
    fn parse_rejects_bad_documents() {
        assert!(CompilePlan::parse("").is_err());
        assert!(CompilePlan::parse("{}").is_err());
        assert!(CompilePlan::parse("{\"schema_version\": 99, \"partitions\": []}").is_err());
        let bad_key = sample().to_json().replace("deadbeef0badf00d", "not-hex");
        assert!(CompilePlan::parse(&bad_key).is_err());
        // Non-numeric node ids are a parse error, not a silent drop.
        let bad_ids = sample().to_json().replace("\"nodes\": [2, 3]", "\"nodes\": [2, \"3\"]");
        assert!(CompilePlan::parse(&bad_ids).is_err());
    }

    /// Satellite: every way a *partition entry* can be malformed is a
    /// loud parse error — dropped fields, wrong types, a non-array
    /// partitions value, corrupted per-partition cache keys and batch
    /// fields. (Round-trips alone never exercise these paths.)
    #[test]
    fn parse_rejects_malformed_partition_entries() {
        let good = sample().to_json();
        assert!(CompilePlan::parse(&good).is_ok(), "surgery base must parse");
        let surgeries: &[(&str, &str, &str)] = &[
            ("partitions is not an array", "\"partitions\": [\n", "\"partitions\": 5, \"unused\": [\n"),
            ("partition missing index", "\"index\": 0, ", ""),
            ("partition index is a string", "\"index\": 0", "\"index\": \"zero\""),
            ("partition missing target", "\"target\": \"xla\", ", ""),
            ("partition target is a number", "\"target\": \"xla\"", "\"target\": 7"),
            ("partition missing nodes", "\"nodes\": [2, 3], ", ""),
            ("partition nodes is an object", "\"nodes\": [2, 3]", "\"nodes\": {}"),
            ("partition missing inputs", "\"inputs\": [0, 1], ", ""),
            ("partition missing outputs", ", \"outputs\": [3]", ""),
            ("partition cache_key not a string", "\"cache_key\": \"0123456789abcdef\"", "\"cache_key\": 81985529216486895"),
            ("partition cache_key not hex", "0123456789abcdef", "0123456789abcdexx"),
            ("batch missing dim", "\"dim\": 0, ", ""),
            ("batch bucket is a string", "\"bucket\": 8", "\"bucket\": \"8\""),
            ("batch padded_inputs not an array", "\"padded_inputs\": [0]", "\"padded_inputs\": 0"),
        ];
        for (why, needle, replacement) in surgeries {
            let mutated = good.replace(needle, replacement);
            assert_ne!(mutated, good, "surgery '{}' did not apply", why);
            assert!(CompilePlan::parse(&mutated).is_err(), "accepted malformed plan: {}", why);
        }
        // Whole-document invariants around partitions.
        assert!(CompilePlan::parse("{\"backend\": \"b\", \"graph\": \"g\", \"cache_key\": \"00\"}").is_err());
        assert!(
            CompilePlan::parse(
                "{\"backend\": \"b\", \"graph\": \"g\", \"cache_key\": \"00\", \"partitions\": [null]}"
            )
            .is_err(),
            "null partition entry"
        );
    }
}
