//! A minimal hand-rolled JSON reader (the offline build has no serde),
//! shared by the `manifest.json` machinery, the `metrics.json` artifact
//! and the bench harness's `BENCH_hotpath.json` merger.
//!
//! Supports objects, arrays, strings (including `\uXXXX` escapes and
//! surrogate pairs), numbers and the three literals. Writers in this crate
//! hand-render their documents; [`escape`] is the shared string escaper.

use super::error::DepyfError;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (first match).
    pub fn get<'a>(&'a self, key: &str) -> Option<&'a Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }
}

/// Escape a string for embedding in a hand-rendered JSON document.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Parse a complete JSON document; trailing garbage is an error.
pub fn parse(text: &str) -> Result<Json, DepyfError> {
    let mut p = Parser::new(text);
    let doc = p.value()?;
    p.skip_ws();
    if p.peek().is_some() {
        return Err(p.err("trailing garbage after document"));
    }
    Ok(doc)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn new(text: &'a str) -> Parser<'a> {
        Parser { bytes: text.as_bytes(), pos: 0 }
    }

    fn err(&self, msg: &str) -> DepyfError {
        DepyfError::Parse(format!("json at byte {}: {}", self.pos, msg))
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), DepyfError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, DepyfError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, DepyfError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", word)))
        }
    }

    fn number(&mut self) -> Result<Json, DepyfError> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c == b'-' || c == b'+' || c == b'.' || c == b'e' || c == b'E' || c.is_ascii_digit() {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).map_err(|_| self.err("bad number"))?;
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    /// Read 4 hex digits at the cursor, advancing past them.
    fn hex4(&mut self) -> Result<u32, DepyfError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn string(&mut self) -> Result<String, DepyfError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            self.pos += 1; // consume 'u'
                            let hi = self.hex4()?;
                            let c = if (0xD800..=0xDBFF).contains(&hi)
                                && self.bytes.get(self.pos) == Some(&b'\\')
                                && self.bytes.get(self.pos + 1) == Some(&b'u')
                            {
                                // Surrogate pair (standard JSON encoding of
                                // non-BMP chars).
                                self.pos += 2;
                                let lo = self.hex4()?;
                                if (0xDC00..=0xDFFF).contains(&lo) {
                                    char::from_u32(0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00))
                                        .unwrap_or('\u{fffd}')
                                } else {
                                    out.push('\u{fffd}');
                                    char::from_u32(lo).unwrap_or('\u{fffd}')
                                }
                            } else {
                                char::from_u32(hi).unwrap_or('\u{fffd}')
                            };
                            out.push(c);
                            continue;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a whole UTF-8 scalar (the text came from &str).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, DepyfError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, DepyfError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_containers() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("  true ").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("[1, \"a\"]").unwrap().as_arr().unwrap().len(), 2);
        let doc = parse("{\"k\": {\"n\": 3}}").unwrap();
        assert_eq!(doc.get("k").unwrap().get("n").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn escape_round_trips_through_parse() {
        let weird = "we\"ird\\na\nme\t\u{1}";
        let doc = parse(&format!("{{\"k\": \"{}\"}}", escape(weird))).unwrap();
        assert_eq!(doc.get("k").unwrap().as_str(), Some(weird));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{} trailing").is_err());
    }
}
