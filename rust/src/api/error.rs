//! [`DepyfError`] — the crate-wide structured error type.
//!
//! Every public layer (session, hijack, backend, dynamo, runtime,
//! decompiler) reports failures through this enum instead of bare
//! `String`s, so callers can match on the failing layer and tooling can
//! map errors to exit codes without string sniffing.

use std::fmt;

use crate::decompiler::DecompileError;
use crate::pylang::CompileError;
use crate::tensor::TensorError;
use crate::value::ValueError;
use crate::vm::VmError;

/// The crate-wide error type. Variants name the layer that failed.
#[derive(Debug)]
pub enum DepyfError {
    /// Filesystem failures (dump directories, artifact files).
    Io(String),
    /// Source, manifest or HLO text that could not be parsed.
    Parse(String),
    /// Graph capture / bytecode compilation failures.
    Compile(String),
    /// VM runtime errors (carries the pylang traceback).
    Vm(VmError),
    /// A graph backend failed to compile or execute a captured graph.
    Backend(String),
    /// A typed tensor-library failure (shape/axis/index) surfaced through
    /// a backend executor — match on [`TensorError::kind`] to distinguish
    /// shape errors from data-range errors without string sniffing.
    Tensor(TensorError),
    /// A typed value-model failure (conversions, truthiness, hashing).
    Value(ValueError),
    /// PJRT runtime failures (client startup, HLO compile, execution).
    Runtime(String),
    /// Bytecode decompilation failures.
    Decompile(String),
    /// `SessionBuilder` misconfiguration, caught at `build()` time.
    Builder(String),
    /// A panic caught by the dispatch path's `catch_unwind` isolation
    /// (backend `plan`/`lower`, `CompiledModule::call`). Carries the
    /// panic payload text; shared locks are never poisoned by it.
    Panic(String),
    /// A deterministic injected fault from the [`crate::faults`] layer
    /// (chaos testing). Never produced in production configurations.
    Fault(String),
    /// A call or compile exceeded its deadline and was abandoned.
    Timeout(String),
    /// Admission control shed the request: the serving queue was full (or
    /// the remaining deadline could not cover the observed service time)
    /// and the job was rejected *before* any work ran. Deliberately not
    /// transient — retrying into an overloaded queue amplifies the
    /// overload; callers should degrade to their fallback immediately.
    Overloaded(String),
}

impl DepyfError {
    /// An [`DepyfError::Io`] with a path/operation context prefix.
    pub fn io(context: impl fmt::Display, err: impl fmt::Display) -> DepyfError {
        DepyfError::Io(format!("{}: {}", context, err))
    }

    /// The layer tag ("io", "parse", ...) — stable across message edits.
    pub fn layer(&self) -> &'static str {
        match self {
            DepyfError::Io(_) => "io",
            DepyfError::Parse(_) => "parse",
            DepyfError::Compile(_) => "compile",
            DepyfError::Vm(_) => "vm",
            DepyfError::Backend(_) => "backend",
            DepyfError::Tensor(_) => "tensor",
            DepyfError::Value(_) => "value",
            DepyfError::Runtime(_) => "runtime",
            DepyfError::Decompile(_) => "decompile",
            DepyfError::Builder(_) => "builder",
            DepyfError::Panic(_) => "panic",
            DepyfError::Fault(_) => "fault",
            DepyfError::Timeout(_) => "timeout",
            DepyfError::Overloaded(_) => "overloaded",
        }
    }

    /// Build a [`DepyfError::Panic`] from a payload caught by
    /// `std::panic::catch_unwind`, extracting the conventional
    /// `&str`/`String` payload text.
    pub fn from_panic(context: &str, payload: Box<dyn std::any::Any + Send>) -> DepyfError {
        let msg = if let Some(s) = payload.downcast_ref::<&str>() {
            (*s).to_string()
        } else if let Some(s) = payload.downcast_ref::<String>() {
            s.clone()
        } else {
            "non-string panic payload".to_string()
        };
        DepyfError::Panic(format!("{} panicked: {}", context, msg))
    }

    /// Whether a retry could plausibly succeed: transient infrastructure
    /// failures (I/O, runtime hiccups, injected faults, isolated panics)
    /// are worth one more attempt; structural failures (shape errors,
    /// unsupported ops, misconfiguration) will fail identically every
    /// time and should degrade immediately.
    pub fn is_transient(&self) -> bool {
        matches!(
            self,
            DepyfError::Io(_) | DepyfError::Runtime(_) | DepyfError::Fault(_) | DepyfError::Panic(_)
        )
    }
}

impl fmt::Display for DepyfError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DepyfError::Vm(e) => write!(f, "vm error: {}", e),
            DepyfError::Tensor(e) => write!(f, "tensor error: {}", e),
            DepyfError::Value(e) => write!(f, "value error: {}", e),
            DepyfError::Io(m)
            | DepyfError::Parse(m)
            | DepyfError::Compile(m)
            | DepyfError::Backend(m)
            | DepyfError::Runtime(m)
            | DepyfError::Decompile(m)
            | DepyfError::Builder(m)
            | DepyfError::Panic(m)
            | DepyfError::Fault(m)
            | DepyfError::Timeout(m)
            | DepyfError::Overloaded(m) => write!(f, "{} error: {}", self.layer(), m),
        }
    }
}

impl std::error::Error for DepyfError {}

impl From<std::io::Error> for DepyfError {
    fn from(e: std::io::Error) -> DepyfError {
        DepyfError::Io(e.to_string())
    }
}

impl From<VmError> for DepyfError {
    fn from(e: VmError) -> DepyfError {
        DepyfError::Vm(e)
    }
}

impl From<TensorError> for DepyfError {
    fn from(e: TensorError) -> DepyfError {
        DepyfError::Tensor(e)
    }
}

impl From<ValueError> for DepyfError {
    fn from(e: ValueError) -> DepyfError {
        DepyfError::Value(e)
    }
}

impl From<CompileError> for DepyfError {
    fn from(e: CompileError) -> DepyfError {
        DepyfError::Parse(e.to_string())
    }
}

impl From<DecompileError> for DepyfError {
    fn from(e: DecompileError) -> DepyfError {
        DepyfError::Decompile(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_failing_layer() {
        assert_eq!(DepyfError::Backend("boom".into()).to_string(), "backend error: boom");
        assert_eq!(DepyfError::Builder("missing dir".into()).to_string(), "builder error: missing dir");
        assert_eq!(DepyfError::Io("x".into()).layer(), "io");
    }

    #[test]
    fn from_vm_error_preserves_traceback() {
        let mut e = VmError::new("division by zero");
        e.traceback.push(("f".into(), 3));
        let d = DepyfError::from(e);
        match &d {
            DepyfError::Vm(inner) => assert_eq!(inner.traceback.len(), 1),
            other => panic!("expected Vm, got {:?}", other),
        }
        assert!(d.to_string().contains("division by zero"));
        assert!(d.to_string().contains("in f"));
    }

    #[test]
    fn from_io_error() {
        let e = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let d = DepyfError::from(e);
        assert_eq!(d.layer(), "io");
        assert!(d.to_string().contains("gone"));
    }

    #[test]
    fn typed_tensor_and_value_variants() {
        let t = DepyfError::from(crate::tensor::TensorError::Shape("cannot broadcast".into()));
        assert_eq!(t.layer(), "tensor");
        match &t {
            DepyfError::Tensor(e) => assert_eq!(e.kind(), "shape"),
            other => panic!("expected Tensor, got {:?}", other),
        }
        let v = DepyfError::from(crate::value::ValueError::AmbiguousTruth);
        assert_eq!(v.layer(), "value");
        assert!(v.to_string().contains("ambiguous"), "{}", v);
    }

    #[test]
    fn io_constructor_adds_context() {
        let d = DepyfError::io("read /tmp/x", "permission denied");
        assert_eq!(d.to_string(), "io error: read /tmp/x: permission denied");
    }

    #[test]
    fn resilience_variants_name_their_layers() {
        assert_eq!(DepyfError::Panic("worker died".into()).to_string(), "panic error: worker died");
        assert_eq!(DepyfError::Fault("injected".into()).layer(), "fault");
        assert_eq!(
            DepyfError::Timeout("call exceeded 50ms".into()).to_string(),
            "timeout error: call exceeded 50ms"
        );
    }

    #[test]
    fn from_panic_extracts_str_and_string_payloads() {
        let caught = std::panic::catch_unwind(|| panic!("boom")).unwrap_err();
        let d = DepyfError::from_panic("backend xla", caught);
        assert_eq!(d.layer(), "panic");
        assert_eq!(d.to_string(), "panic error: backend xla panicked: boom");
        let caught = std::panic::catch_unwind(|| panic!("{} exploded", "stage")).unwrap_err();
        let d = DepyfError::from_panic("pipeline", caught);
        assert!(d.to_string().contains("pipeline panicked: stage exploded"), "{}", d);
    }

    #[test]
    fn transience_splits_retryable_from_structural() {
        assert!(DepyfError::Io("disk blip".into()).is_transient());
        assert!(DepyfError::Runtime("pjrt hiccup".into()).is_transient());
        assert!(DepyfError::Fault("injected".into()).is_transient());
        assert!(DepyfError::Panic("caught".into()).is_transient());
        assert!(!DepyfError::Compile("bad shape".into()).is_transient());
        assert!(!DepyfError::Backend("unsupported op".into()).is_transient());
        assert!(!DepyfError::Timeout("deadline".into()).is_transient());
        assert!(!DepyfError::Builder("misconfigured".into()).is_transient());
        // A shed is a capacity decision, not a hiccup: retrying into an
        // overloaded queue amplifies the overload, so degrade instead.
        assert!(!DepyfError::Overloaded("queue full".into()).is_transient());
    }

    #[test]
    fn overloaded_names_its_layer() {
        let e = DepyfError::Overloaded("queue full (cap 4); request shed".into());
        assert_eq!(e.layer(), "overloaded");
        assert_eq!(e.to_string(), "overloaded error: queue full (cap 4); request shed");
    }
}
