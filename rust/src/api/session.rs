//! [`Session`] and its fluent [`SessionBuilder`] — the single entry point
//! to depyf's two workflows (the paper's two context managers):
//!
//! ```text
//! // with depyf.prepare_debug(dir): capture + dump everything
//! let mut s = Session::builder().dump_to(dir).build()?;
//! s.run_source("main", src)?;
//! let artifacts = s.finish()?;          // typed Artifacts + manifest.json
//!
//! // with depyf.debug(): step through compiled-graph dump lines
//! let mut s = Session::builder().dump_to(dir).trace(TraceMode::StepGraphs).build()?;
//! s.debugger.break_at("__compiled_fn_1.py", 3);
//! s.run_source("main", src)?;
//! ```
//!
//! The builder subsumes the old `prepare_debug` / `prepare_debug_with_runtime`
//! / `debug` constructors (kept as deprecated shims in [`crate::session`]):
//! any registered [`Backend`] can be plugged in, the ISA version and
//! fallback policy are explicit, and `finish()` returns typed
//! [`Artifact`]s plus a machine-readable `manifest.json`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::Arc;

use crate::bytecode::IsaVersion;
use crate::debugger::Debugger;
use crate::dynamo::{Dynamo, DynamoConfig, GraphTracer};
use crate::graph::opt::{render_optimized_json, OptLevel, Optimized};
use crate::graph::{print_graph, print_graph_with_lines};
use crate::hijack::{dump_all, link_source, DumpDir};
use crate::runtime::Runtime;
use crate::value::Value;
use crate::vm::{Vm, VmError};

use super::artifact::{write_manifest, Artifact, ArtifactKind};
use super::backend::{backend_names, lookup_backend, Backend, Capabilities, EagerBackend, FallbackPolicy};
use super::error::DepyfError;

/// How captured graphs execute inside the session.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TraceMode {
    /// Compile with the configured backend; no per-node callbacks
    /// (`depyf.prepare_debug`).
    #[default]
    Capture,
    /// Route graphs through the traced eager executor so the debugger can
    /// stop on `__compiled_fn_*.py` lines (`depyf.debug`). Overrides the
    /// backend choice — stepping requires the eager executor.
    StepGraphs,
}

/// Adapter: dynamo per-node graph events → debugger stops at dump lines.
struct GraphDebugAdapter {
    dump_root: PathBuf,
    debugger: Rc<Debugger>,
    /// graph name -> (node id -> line) — filled lazily as graphs compile.
    tables: std::cell::RefCell<HashMap<String, HashMap<usize, u32>>>,
    /// Weak: the dynamo's config holds this adapter (as tracer), so a
    /// strong reference here would cycle and leak every session's graphs,
    /// code objects and log.
    dynamo: std::cell::RefCell<Option<std::rc::Weak<Dynamo>>>,
}

impl GraphTracer for GraphDebugAdapter {
    fn on_node(&self, graph_name: &str, node_id: usize, value: &crate::tensor::Tensor) {
        // Resolve (or build) the line table for this graph straight from
        // the printer — the single source of truth for dump layout.
        let line = {
            let mut tables = self.tables.borrow_mut();
            if !tables.contains_key(graph_name) {
                if let Some(d) = self.dynamo.borrow().as_ref().and_then(|w| w.upgrade()) {
                    if let Some((_, g)) = d.graphs().iter().find(|(n, _)| n == graph_name) {
                        tables.insert(graph_name.to_string(), print_graph_with_lines(g).1);
                    }
                }
            }
            tables.get(graph_name).and_then(|t| t.get(&node_id)).copied()
        };
        if let Some(line) = line {
            let file = self.dump_root.join(format!("{}.py", graph_name));
            self.debugger.graph_stop(&file.to_string_lossy(), line, graph_name, &format!("{}", value));
        }
    }
}

/// A depyf debugging session: a VM wired to a dynamo instance whose every
/// artifact lands in a [`DumpDir`].
pub struct Session {
    pub vm: Vm,
    pub dynamo: Rc<Dynamo>,
    pub dump: DumpDir,
    pub debugger: Rc<Debugger>,
    adapter: Rc<GraphDebugAdapter>,
    version: IsaVersion,
}

impl std::fmt::Debug for Session {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "<depyf session: backend {}, dump {}>",
            self.dynamo.config.backend.name(),
            self.dump.root().display()
        )
    }
}

/// Fluent configuration for [`Session`]; see the module docs for the shape.
pub struct SessionBuilder {
    dir: Option<PathBuf>,
    backend: Option<Arc<dyn Backend>>,
    backend_name: Option<String>,
    isa: IsaVersion,
    runtime: Option<Arc<Runtime>>,
    trace: TraceMode,
    fallback: FallbackPolicy,
    require: Capabilities,
    opt_level: OptLevel,
}

impl Session {
    /// Start configuring a session. `dump_to(dir)` is the only required
    /// call; everything else defaults (eager backend, ISA 3.11,
    /// `TraceMode::Capture`, `FallbackPolicy::Eager`).
    pub fn builder() -> SessionBuilder {
        SessionBuilder {
            dir: None,
            backend: None,
            backend_name: None,
            isa: IsaVersion::V311,
            runtime: None,
            trace: TraceMode::Capture,
            fallback: FallbackPolicy::Eager,
            require: Capabilities::NONE,
            opt_level: OptLevel::default(),
        }
    }

    /// Override the ISA version used by [`Session::run_source`].
    pub fn set_version(&mut self, v: IsaVersion) {
        self.version = v;
    }

    /// Run a source program inside the session. The source is hijacked into
    /// the dump dir first, so the debugger reports dump-relative locations.
    pub fn run_source(&mut self, name: &str, src: &str) -> Result<Value, VmError> {
        let path = link_source(&self.dump, name, src).map_err(|e| VmError::new(e.to_string()))?;
        let code = crate::pylang::compile_module(src, &path.to_string_lossy(), self.version)
            .map_err(|e| VmError::new(e.to_string()))?;
        self.vm.run_module(&code)
    }

    /// Write all dumps (`full_code.py`, `__compiled_fn_*.py`,
    /// `__transformed_*.py`, disassembly, guards), every backend module's
    /// artifacts (compile plans, per-partition HLO), the optimizer's
    /// `__optimized_*.{txt,json}` before/after dumps, a `metrics.json`
    /// snapshot of the compiler counters (with per-module stats incl.
    /// pass deltas) and a `manifest.json` index, and return the typed
    /// artifact list.
    pub fn finish(&self) -> Result<Vec<Artifact>, DepyfError> {
        dump_all(&self.dynamo, &self.dump)?;
        // Backend-module artifacts: compile plans, per-partition/bucket
        // HLO — whatever each CompiledModule wants on disk.
        for f in self.dynamo.compiled() {
            for art in f.module.artifacts() {
                self.dump.write_refresh(art.kind, &art.name, &art.file, &art.content)?;
            }
        }
        // The optimizer's before/after story, next to the original
        // `__compiled_fn_*.py`: a human-diffable .txt (pass table + the
        // optimized graph printed like the original dump) and a lossless
        // .json (serde graph + pass stats). Skipped at -O0, where the
        // optimized graph IS the original.
        let optimizations = self.dynamo.optimizations();
        for (name, opt) in &optimizations {
            if opt.level == OptLevel::O0 {
                continue;
            }
            self.dump.write_refresh(
                ArtifactKind::OptimizedGraph,
                name,
                &format!("__optimized_{}.txt", sanitize_stem(name)),
                &render_optimized_txt(name, opt),
            )?;
            self.dump.write_refresh(
                ArtifactKind::OptimizedGraph,
                &format!("{}.json", name),
                &format!("__optimized_{}.json", sanitize_stem(name)),
                &render_optimized_json(name, opt),
            )?;
        }
        // Per-session perf observability: cache hits/misses, guard
        // checks/failures, evictions, compile_ns, plus per-module backend
        // stats and optimizer pass deltas — so regressions (and
        // partition/bucket/rewrite decisions) show up in dumps. The
        // snapshot folds in the dispatch-path resilience counters
        // (retries, degraded calls, timeouts, caught panics).
        let modules_json = render_modules_json(&self.dynamo.compiled(), &optimizations);
        self.dump.write_refresh(
            ArtifactKind::Metrics,
            "metrics",
            "metrics.json",
            &self.dynamo.metrics_snapshot().to_json_with(Some(("modules", &modules_json))),
        )?;
        let artifacts = self.dump.artifacts();
        write_manifest(self.dump.root(), &artifacts)?;
        let _ = &self.adapter;
        Ok(artifacts)
    }
}

fn sanitize_stem(name: &str) -> String {
    name.chars().map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' }).collect()
}

/// The `__optimized_*.txt` artifact: a commented pass table followed by
/// the optimized graph printed exactly like `__compiled_fn_*.py`, so
/// `diff __compiled_fn_1.py __optimized___compiled_fn_1.txt` shows what
/// the optimizer did.
fn render_optimized_txt(name: &str, opt: &Optimized) -> String {
    let mut out = format!("# optimizer report for {} (opt-level {})\n", name, opt.level);
    for p in &opt.passes {
        out.push_str(&format!(
            "#   {:<12} nodes {:>4} -> {:<4} rewrites {}\n",
            p.pass, p.nodes_before, p.nodes_after, p.rewrites
        ));
    }
    out.push_str("#\n");
    out.push_str(&print_graph(&opt.graph));
    out.push_str("# ^ optimized graph (diff against the __compiled_fn dump)\n");
    out
}

/// Render the `"modules"` array for `metrics.json`: one entry per
/// compiled graph with its backend, call count, module stats and the
/// optimizer pass deltas that shaped its planned graph.
fn render_modules_json(
    compiled: &[Rc<crate::graph::CompiledGraphFn>],
    optimizations: &[(String, Arc<Optimized>)],
) -> String {
    let opt_json = |name: &str| -> String {
        let Some((_, opt)) = optimizations.iter().find(|(n, _)| n == name) else {
            return "null".into();
        };
        let passes: Vec<String> = opt
            .passes
            .iter()
            .map(|p| {
                format!(
                    "{{\"pass\": \"{}\", \"nodes_before\": {}, \"nodes_after\": {}, \"rewrites\": {}}}",
                    p.pass, p.nodes_before, p.nodes_after, p.rewrites
                )
            })
            .collect();
        format!("{{\"level\": {}, \"passes\": [{}]}}", opt.level.as_u8(), passes.join(", "))
    };
    let mut out = String::from("[\n");
    for (i, f) in compiled.iter().enumerate() {
        let stats = f.module.stats();
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"backend\": \"{}\", \"calls\": {}, \"partitions\": {}, \"bucket\": {}, \"cache_hits\": {}, \"opt\": {}}}{}\n",
            super::json::escape(&f.name),
            super::json::escape(&f.backend_name),
            f.calls.get(),
            stats.partitions,
            stats.bucket.map(|b| b.to_string()).unwrap_or_else(|| "null".into()),
            stats.cache_hits,
            opt_json(&f.name),
            if i + 1 < compiled.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]");
    out
}

impl SessionBuilder {
    /// Where dump files land (required).
    pub fn dump_to(mut self, dir: impl AsRef<Path>) -> SessionBuilder {
        self.dir = Some(dir.as_ref().to_path_buf());
        self
    }

    /// Compile captured graphs with this backend instance.
    pub fn backend(mut self, backend: Arc<dyn Backend>) -> SessionBuilder {
        self.backend = Some(backend);
        self.backend_name = None;
        self
    }

    /// Compile captured graphs with a registered backend, looked up by name
    /// at `build()` time (like `torch.compile(backend="name")`).
    pub fn backend_named(mut self, name: impl Into<String>) -> SessionBuilder {
        self.backend_name = Some(name.into());
        self.backend = None;
        self
    }

    /// ISA version for sources run through the session.
    pub fn isa(mut self, v: IsaVersion) -> SessionBuilder {
        self.isa = v;
        self
    }

    /// PJRT runtime for backends that lower to HLO (e.g. `xla`).
    pub fn runtime(mut self, rt: Arc<Runtime>) -> SessionBuilder {
        self.runtime = Some(rt);
        self
    }

    /// Capture-only (default) or step-through-graphs tracing.
    pub fn trace(mut self, mode: TraceMode) -> SessionBuilder {
        self.trace = mode;
        self
    }

    /// What to do when the backend fails on a captured graph.
    pub fn fallback(mut self, policy: FallbackPolicy) -> SessionBuilder {
        self.fallback = policy;
        self
    }

    /// Graph-optimizer level applied at `Backend::plan` time for every
    /// captured graph (`--opt-level`; default 2 — folding, CSE, DCE,
    /// algebraic rewrites and eager elementwise fusion).
    pub fn opt_level(mut self, level: OptLevel) -> SessionBuilder {
        self.opt_level = level;
        self
    }

    /// Demand capabilities of the configured backend. Under
    /// [`FallbackPolicy::Error`] a backend lacking any of them is rejected
    /// at `build()` time — misconfiguration fails up front, not
    /// mid-compile. (Under the default eager policy the fallback executor
    /// absorbs whatever the backend cannot do, so the session builds.)
    pub fn require(mut self, caps: Capabilities) -> SessionBuilder {
        self.require = self.require | caps;
        self
    }

    /// Validate the configuration and wire up the session.
    pub fn build(self) -> Result<Session, DepyfError> {
        let dir = self
            .dir
            .ok_or_else(|| DepyfError::Builder("SessionBuilder: dump_to(dir) is required".into()))?;
        let backend: Arc<dyn Backend> = match (self.backend, self.backend_name) {
            (Some(b), _) => b,
            (None, Some(name)) => lookup_backend(&name).ok_or_else(|| {
                DepyfError::Builder(format!(
                    "unknown backend '{}' (registered: {})",
                    name,
                    backend_names().join(", ")
                ))
            })?,
            (None, None) => Arc::new(EagerBackend),
        };
        // StepGraphs routes every graph through the traced eager executor,
        // so the backend is never consulted and needs no runtime.
        let backend_consulted = self.trace != TraceMode::StepGraphs;
        if backend.requires_runtime()
            && self.runtime.is_none()
            && self.fallback == FallbackPolicy::Error
            && backend_consulted
        {
            return Err(DepyfError::Builder(format!(
                "backend '{}' requires a runtime (SessionBuilder::runtime) under FallbackPolicy::Error",
                backend.name()
            )));
        }
        let missing = backend.capabilities().missing(self.require);
        if !missing.is_empty() && self.fallback == FallbackPolicy::Error && backend_consulted {
            return Err(DepyfError::Builder(format!(
                "backend '{}' lacks required capabilities: {} (declared: {})",
                backend.name(),
                missing,
                backend.capabilities()
            )));
        }
        let dump = DumpDir::create(&dir)?;
        let debugger = Debugger::shared();
        let adapter = Rc::new(GraphDebugAdapter {
            dump_root: dump.root().to_path_buf(),
            debugger: Rc::clone(&debugger),
            tables: Default::default(),
            dynamo: std::cell::RefCell::new(None),
        });
        let config = DynamoConfig {
            backend,
            fallback: self.fallback,
            opt_level: self.opt_level,
            tracer: if self.trace == TraceMode::StepGraphs {
                Some(adapter.clone() as Rc<dyn GraphTracer>)
            } else {
                None
            },
            ..Default::default()
        };
        let dynamo = match self.runtime {
            Some(rt) => Dynamo::with_runtime(config, rt),
            None => Dynamo::new(config),
        };
        *adapter.dynamo.borrow_mut() = Some(Rc::downgrade(&dynamo));
        let mut vm = Vm::new();
        vm.eval_hook = Some(dynamo.clone());
        vm.tracer = Some(debugger.clone());
        Ok(Session { vm, dynamo, dump, debugger, adapter, version: self.isa })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{load_manifest, ArtifactKind};

    fn tmpdir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("depyf_session_{}_{}", tag, std::process::id()))
    }

    #[test]
    fn builder_dumps_everything_with_manifest() {
        let dir = tmpdir("prep");
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = Session::builder().dump_to(&dir).build().unwrap();
        s.run_source(
            "main",
            "def f(x):\n    y = x * 2\n    print('mid')\n    return y.sum()\nprint(f(torch.ones([3])).item())\n",
        )
        .unwrap();
        let artifacts = s.finish().unwrap();
        assert!(artifacts.iter().any(|a| a.kind == ArtifactKind::FullCode), "{:?}", artifacts);
        assert!(artifacts.iter().any(|a| a.kind == ArtifactKind::CompiledGraph), "{:?}", artifacts);
        assert!(artifacts.iter().any(|a| a.kind == ArtifactKind::Source && a.name == "main"), "{:?}", artifacts);
        let transformed: Vec<&Artifact> =
            artifacts.iter().filter(|a| a.kind == ArtifactKind::TransformedSource).collect();
        assert!(!transformed.is_empty(), "{:?}", artifacts);
        let content = std::fs::read_to_string(&transformed[0].path).unwrap();
        assert!(content.contains("__compiled_fn_"), "{}", content);
        // The manifest round-trips and indexes exactly what finish() returned.
        let indexed = load_manifest(&dir).unwrap();
        assert_eq!(indexed, artifacts);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn finish_dumps_session_metrics() {
        let dir = tmpdir("metrics");
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = Session::builder().dump_to(&dir).build().unwrap();
        s.run_source(
            "main",
            "def f(x):\n    return (x * 2).sum()\nprint(f(torch.ones([2])).item())\nprint(f(torch.ones([2])).item())\n",
        )
        .unwrap();
        let artifacts = s.finish().unwrap();
        let m = artifacts.iter().find(|a| a.kind == ArtifactKind::Metrics).expect("metrics artifact");
        let doc = crate::api::json::parse(&std::fs::read_to_string(&m.path).unwrap()).unwrap();
        assert_eq!(doc.get("captures").and_then(|v| v.as_f64()), Some(1.0));
        assert!(doc.get("cache_hits").and_then(|v| v.as_f64()).unwrap() >= 1.0);
        assert!(doc.get("compile_ns").and_then(|v| v.as_f64()).unwrap() > 0.0);
        // Repeated finish() refreshes the same file, no duplicates.
        let again = s.finish().unwrap();
        assert_eq!(again.iter().filter(|a| a.kind == ArtifactKind::Metrics).count(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn finish_dumps_optimized_graph_artifacts() {
        let dir = tmpdir("opt");
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = Session::builder().dump_to(&dir).build().unwrap();
        // A graph with a foldable const chain and a fusible elementwise run.
        s.run_source(
            "main",
            "def f(x):\n    k = 2.0 * 3.0\n    return ((x * k).relu() * 1.0).sum()\nprint(f(torch.ones([4])).item())\n",
        )
        .unwrap();
        let artifacts = s.finish().unwrap();
        let opts: Vec<&Artifact> =
            artifacts.iter().filter(|a| a.kind == ArtifactKind::OptimizedGraph).collect();
        assert_eq!(opts.len(), 2, "one .txt + one .json per graph: {:?}", artifacts);
        let txt = opts.iter().find(|a| a.path.to_string_lossy().ends_with(".txt")).unwrap();
        let body = std::fs::read_to_string(&txt.path).unwrap();
        assert!(body.contains("optimizer report"), "{}", body);
        assert!(body.contains("const_fold"), "{}", body);
        assert!(body.contains("def __compiled_fn_1"), "{}", body);
        let js = opts.iter().find(|a| a.path.to_string_lossy().ends_with(".json")).unwrap();
        let doc = crate::api::json::parse(&std::fs::read_to_string(&js.path).unwrap()).unwrap();
        assert_eq!(doc.get("level").and_then(|v| v.as_f64()), Some(2.0));
        // The embedded graph is the optimizer's output, parseable losslessly.
        let g = crate::graph::serde::graph_from_value(doc.get("graph").unwrap()).unwrap();
        assert!(g.num_ops() < 4, "folding + x*1 should shrink the graph: {:?}", g);
        // The manifest indexes the new kind, and metrics.json carries the
        // per-module pass deltas.
        let indexed = load_manifest(&dir).unwrap();
        assert!(indexed.iter().any(|a| a.kind == ArtifactKind::OptimizedGraph));
        let m = artifacts.iter().find(|a| a.kind == ArtifactKind::Metrics).unwrap();
        let mdoc = crate::api::json::parse(&std::fs::read_to_string(&m.path).unwrap()).unwrap();
        let modules = match mdoc.get("modules") {
            Some(crate::api::json::Json::Arr(items)) => items,
            other => panic!("modules array missing: {:?}", other),
        };
        assert!(modules[0].get("opt").and_then(|o| o.get("passes")).is_some(), "{:?}", modules);
        // At -O0 no optimized artifacts appear.
        let dir0 = tmpdir("opt0");
        let _ = std::fs::remove_dir_all(&dir0);
        let mut s0 =
            Session::builder().dump_to(&dir0).opt_level(crate::api::OptLevel::O0).build().unwrap();
        s0.run_source("main", "def f(x):\n    return (x * 2).sum()\nprint(f(torch.ones([2])).item())\n")
            .unwrap();
        let a0 = s0.finish().unwrap();
        assert!(a0.iter().all(|a| a.kind != ArtifactKind::OptimizedGraph), "{:?}", a0);
        std::fs::remove_dir_all(&dir).ok();
        std::fs::remove_dir_all(&dir0).ok();
    }

    #[test]
    fn debugger_steps_compiled_graph_lines() {
        let dir = tmpdir("dbg");
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = Session::builder().dump_to(&dir).trace(TraceMode::StepGraphs).build().unwrap();
        // Break on line 3 of the first compiled graph (second op node).
        s.debugger.break_at("__compiled_fn_1.py", 3);
        s.run_source("main", "def f(x):\n    return (x * 2 + 1).sum()\nprint(f(torch.ones([4])).item())\n")
            .unwrap();
        let evs = s.debugger.events();
        let graph_stops: Vec<_> = evs.iter().filter(|e| e.file.ends_with("__compiled_fn_1.py")).collect();
        assert_eq!(graph_stops.len(), 1, "{:?}", evs);
        assert_eq!(graph_stops[0].line, 3);
        // The stop carries the intermediate tensor value.
        assert!(graph_stops[0].locals[0].1.contains("tensor"), "{:?}", graph_stops[0].locals);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn source_breakpoints_respect_dump_paths() {
        let dir = tmpdir("src");
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = Session::builder().dump_to(&dir).build().unwrap();
        s.debugger.break_at("main.py", 2);
        s.run_source("main", "x = 1\ny = x + 1\nprint(y)\n").unwrap();
        let evs = s.debugger.events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].line, 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn builder_requires_dump_dir() {
        let err = Session::builder().build().unwrap_err();
        assert_eq!(err.layer(), "builder");
        assert!(err.to_string().contains("dump_to"), "{}", err);
    }

    #[test]
    fn builder_rejects_unknown_backend_name() {
        let dir = tmpdir("unknown_backend");
        let err = Session::builder().dump_to(&dir).backend_named("no-such-backend").build().unwrap_err();
        assert_eq!(err.layer(), "builder");
        assert!(err.to_string().contains("no-such-backend"), "{}", err);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn builder_rejects_runtimeless_xla_under_error_policy() {
        let dir = tmpdir("xla_err");
        let err = Session::builder()
            .dump_to(&dir)
            .backend_named("xla")
            .fallback(FallbackPolicy::Error)
            .build()
            .unwrap_err();
        assert_eq!(err.layer(), "builder");
        assert!(err.to_string().contains("requires a runtime"), "{}", err);
        // Under the default Eager policy the same configuration builds (and
        // degrades per-graph, recording the reason).
        let s = Session::builder().dump_to(&dir).backend_named("xla").build().unwrap();
        drop(s);
        // StepGraphs never consults the backend, so it builds even under
        // FallbackPolicy::Error with no runtime.
        let s = Session::builder()
            .dump_to(&dir)
            .backend_named("xla")
            .fallback(FallbackPolicy::Error)
            .trace(TraceMode::StepGraphs)
            .build()
            .unwrap();
        drop(s);
        std::fs::remove_dir_all(&dir).ok();
    }
}
