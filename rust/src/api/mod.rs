//! `depyf::api` — the unified public entry point.
//!
//! This layer packages the whole stack behind four small, typed surfaces:
//!
//! * [`Session`] / [`SessionBuilder`] — the paper's two context managers
//!   (`prepare_debug`, `debug`) as one fluent builder:
//!   `Session::builder().backend_named("xla").isa(IsaVersion::V311)
//!   .dump_to(dir).trace(TraceMode::StepGraphs).build()?`.
//! * [`Backend`] + [`register_backend`] — pluggable graph compilers with an
//!   explicit [`FallbackPolicy`], mirroring `torch.compile(backend=...)`.
//! * [`Artifact`] / [`ArtifactKind`] — typed dump artifacts returned by
//!   `finish()`, indexed by a machine-readable `manifest.json`.
//! * [`DepyfError`] — the crate-wide structured error type; no public API
//!   returns `Result<_, String>`.
//!
//! The older per-module entry points (`session::DebugSession`,
//! `backend::compile_graph`) remain as thin deprecated shims over this
//! module.

mod artifact;
mod backend;
mod error;
pub mod json;
mod session;

pub use artifact::{
    load_manifest, parse_manifest, render_manifest, write_manifest, Artifact, ArtifactKind, MANIFEST_FILE,
    MANIFEST_SCHEMA_VERSION,
};
pub use backend::{
    backend_names, compile_with_policy, eager_graph_fn, lookup_backend, register_backend, Backend,
    CompileCtx, EagerBackend, FallbackPolicy, PolicyCompiled, XlaBackend,
};
pub use error::DepyfError;
pub use session::{Session, SessionBuilder, TraceMode};
