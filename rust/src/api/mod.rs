//! `depyf::api` — the unified public entry point.
//!
//! This layer packages the whole stack behind five small, typed surfaces:
//!
//! * [`Session`] / [`SessionBuilder`] — the paper's two context managers
//!   (`prepare_debug`, `debug`) as one fluent builder:
//!   `Session::builder().backend_named("sharded").isa(IsaVersion::V311)
//!   .dump_to(dir).trace(TraceMode::StepGraphs).build()?`.
//! * The staged backend pipeline — a typed [`CompileRequest`] (graph,
//!   input specs, guard context, cache key, verbosity) flows through
//!   [`Backend::plan`] (a declarative, dumpable [`CompilePlan`]:
//!   partitions, padding/bucketing, per-partition targets) and
//!   [`Backend::lower`] (an executable [`CompiledModule`] with
//!   `artifacts()` and `stats()`). A [`Capabilities`] bitset lets the
//!   registry, [`SessionBuilder`] and [`FallbackPolicy`] validate
//!   configurations up front. Built-ins: `eager`, `xla`, `sharded`,
//!   `batched`; [`register_backend`] plugs in custom compilers, mirroring
//!   `torch.compile(backend=...)`.
//! * [`Artifact`] / [`ArtifactKind`] — typed dump artifacts returned by
//!   `finish()`, indexed by a machine-readable `manifest.json` (compile
//!   plans and per-partition HLO included).
//! * [`DepyfError`] — the crate-wide structured error type; no public API
//!   returns `Result<_, String>`, and tensor/value failures stay typed
//!   ([`DepyfError::Tensor`] / [`DepyfError::Value`]) down to the op
//!   library.

mod artifact;
mod backend;
mod error;
pub mod json;
pub mod plan;
mod session;
pub mod trace;

pub use artifact::{
    load_manifest, parse_manifest, render_manifest, write_manifest, Artifact, ArtifactKind, MANIFEST_FILE,
    MANIFEST_SCHEMA_VERSION,
};
pub use backend::{
    backend_names, compile_with_policy, eager_graph_fn, lookup_backend, module_from_fn,
    register_backend, Backend, Capabilities, CompileRequest, CompiledModule, EagerBackend,
    FallbackPolicy, FnModule, InputSpec, ModuleArtifact, ModuleStats, PolicyCompiled, XlaBackend,
};
pub use crate::graph::opt::{OptLevel, Optimized, PassStat};
pub use error::DepyfError;
pub use plan::{BatchPlan, CompilePlan, OptSummary, PartitionPlan, PassDelta, PLAN_SCHEMA_VERSION};
pub use session::{Session, SessionBuilder, TraceMode};
pub use trace::{TraceBundle, TraceCall, TRACE_SCHEMA_VERSION};
