//! The staged backend pipeline: a typed [`CompileRequest`] flows through
//! [`Backend::plan`] (a declarative, dumpable [`CompilePlan`]) and
//! [`Backend::lower`] (an executable [`CompiledModule`]), with a
//! [`Capabilities`] bitset so the registry, `SessionBuilder` and
//! [`FallbackPolicy`] can validate configurations up front instead of
//! failing mid-compile.
//!
//! `eager`, `xla`, `sharded` and `batched` are the built-in backends;
//! [`register_backend`] plugs custom compilers into dynamo and
//! [`crate::api::SessionBuilder`] without touching this crate — the
//! analogue of `torch.compile(backend=...)` accepting both built-in names
//! and custom callables.

use std::collections::HashMap;
use std::fmt;
use std::ops::BitOr;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::rc::Rc;
use std::sync::{Arc, Mutex, OnceLock, PoisonError, RwLock};

use crate::backend::{
    batched::BatchedBackend, eager, recording::RecordingBackend, sharded::ShardedBackend, xla,
};
use crate::dynamo::Verbosity;
use crate::graph::opt::{optimize, OptLevel, Optimized};
use crate::graph::{CompiledGraphFn, Graph};
use crate::runtime::Runtime;
use crate::tensor::Tensor;

use super::artifact::ArtifactKind;
use super::error::DepyfError;
use super::plan::CompilePlan;

/// What dynamo does when a backend fails to plan or lower a captured graph.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FallbackPolicy {
    /// Degrade to the eager reference executor (how torch.compile backends
    /// behave); the reason is recorded in the compiled fn's `backend_name`
    /// and in the frontend log — never silently.
    #[default]
    Eager,
    /// Propagate the backend error instead of degrading.
    Error,
}

/// A small capability bitset declared by every [`Backend`], checked by the
/// registry, `SessionBuilder::build()` and the CLI *before* any graph is
/// compiled.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Capabilities(u32);

impl Capabilities {
    pub const NONE: Capabilities = Capabilities(0);
    /// Can split one captured graph into several executables.
    pub const PARTITION: Capabilities = Capabilities(1 << 0);
    /// Can pad/bucket a dynamic leading dim so one executable serves
    /// multiple guard entries.
    pub const DYNAMIC_BATCH: Capabilities = Capabilities(1 << 1);
    /// Modules expose future-returning submission on top of `call` —
    /// the `async` wrapper backend ([`crate::serve::AsyncBackend`])
    /// dispatches calls to a worker pool and returns
    /// [`crate::serve::CallFuture`]s.
    pub const ASYNC: Capabilities = Capabilities(1 << 2);
    /// Cannot lower without a PJRT runtime (`SessionBuilder::runtime`).
    pub const REQUIRES_RUNTIME: Capabilities = Capabilities(1 << 3);
    /// Lowers to PJRT when a runtime is present, degrades to eager
    /// executables otherwise (the CLI provisions the shared runtime).
    pub const USES_RUNTIME: Capabilities = Capabilities(1 << 4);
    /// Decorates another backend's modules (e.g. `recording`) instead of
    /// compiling itself; everything else it declares is inherited from the
    /// wrapped backend.
    pub const WRAPPER: Capabilities = Capabilities(1 << 5);

    pub fn contains(self, other: Capabilities) -> bool {
        self.0 & other.0 == other.0
    }

    pub fn is_empty(self) -> bool {
        self.0 == 0
    }

    /// Capabilities in `required` that `self` lacks.
    pub fn missing(self, required: Capabilities) -> Capabilities {
        Capabilities(required.0 & !self.0)
    }
}

impl BitOr for Capabilities {
    type Output = Capabilities;
    fn bitor(self, rhs: Capabilities) -> Capabilities {
        Capabilities(self.0 | rhs.0)
    }
}

impl fmt::Display for Capabilities {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut names = Vec::new();
        for (bit, name) in [
            (Capabilities::PARTITION, "partition"),
            (Capabilities::DYNAMIC_BATCH, "dynamic_batch"),
            (Capabilities::ASYNC, "async"),
            (Capabilities::REQUIRES_RUNTIME, "requires_runtime"),
            (Capabilities::USES_RUNTIME, "uses_runtime"),
            (Capabilities::WRAPPER, "wrapper"),
        ] {
            if self.contains(bit) {
                names.push(name);
            }
        }
        if names.is_empty() {
            f.write_str("none")
        } else {
            f.write_str(&names.join("|"))
        }
    }
}

/// One example input of a captured graph: the placeholder name and the
/// concrete shape it was specialized to (guards pin these shapes).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

/// Everything a backend may need at compile time, as one typed request:
/// the captured graph, its example-input specs, the guard context that
/// specialized it, the content-hash cache key, verbosity, the optional
/// PJRT runtime and the failure policy.
pub struct CompileRequest {
    /// The installed global's name (`__compiled_fn_N`).
    pub name: String,
    pub graph: Arc<Graph>,
    /// Placeholder names + concrete shapes, in input order.
    pub input_specs: Vec<InputSpec>,
    /// Human-readable guard descriptions attached to this entry.
    pub guards: Vec<String>,
    /// `Graph::content_hash()` — the process/disk compile-cache key.
    pub cache_key: u64,
    pub verbosity: Verbosity,
    /// PJRT runtime, for backends that lower to HLO.
    pub runtime: Option<Arc<Runtime>>,
    /// Applied by the caller driving [`compile_with_policy`] — backends
    /// themselves must NOT apply it; they report failures and let the
    /// policy decide.
    pub fallback: FallbackPolicy,
    /// Optimizer level the plan stage applies (`--opt-level`, default 2).
    pub opt_level: OptLevel,
    /// Memoized optimizer output: `plan` and `lower` share one run.
    /// A `Mutex` (not `RefCell`) so requests can be handed to compile
    /// worker threads; it is only ever locked briefly, never across a
    /// compile.
    opt: Mutex<Option<Arc<Optimized>>>,
}

impl Clone for CompileRequest {
    fn clone(&self) -> CompileRequest {
        CompileRequest {
            name: self.name.clone(),
            graph: Arc::clone(&self.graph),
            input_specs: self.input_specs.clone(),
            guards: self.guards.clone(),
            cache_key: self.cache_key,
            verbosity: self.verbosity,
            runtime: self.runtime.clone(),
            fallback: self.fallback,
            opt_level: self.opt_level,
            opt: Mutex::new(self.opt.lock().unwrap_or_else(PoisonError::into_inner).clone()),
        }
    }
}

impl CompileRequest {
    /// A request with defaults (no guards, no runtime, `Info` verbosity,
    /// eager fallback, `--opt-level 2`); input specs and cache key derive
    /// from the graph.
    pub fn new(name: &str, graph: Arc<Graph>) -> CompileRequest {
        let input_specs = graph
            .input_shapes()
            .into_iter()
            .map(|(name, shape)| InputSpec { name, shape })
            .collect();
        let cache_key = graph.content_hash();
        CompileRequest {
            name: name.to_string(),
            graph,
            input_specs,
            guards: Vec::new(),
            cache_key,
            verbosity: Verbosity::default(),
            runtime: None,
            fallback: FallbackPolicy::default(),
            opt_level: OptLevel::default(),
            opt: Mutex::new(None),
        }
    }

    /// Run the `graph::opt` pipeline at this request's level, once —
    /// every backend's `plan` and `lower` stage works on
    /// `optimized().graph` (at `O0` that is the captured graph itself).
    pub fn optimized(&self) -> Arc<Optimized> {
        let mut slot = self.opt.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(o) = slot.as_ref() {
            return Arc::clone(o);
        }
        let o = Arc::new(optimize(&self.graph, self.opt_level));
        *slot = Some(Arc::clone(&o));
        o
    }

    pub fn with_opt_level(mut self, level: OptLevel) -> CompileRequest {
        self.opt_level = level;
        *self.opt.lock().unwrap_or_else(PoisonError::into_inner) = None;
        self
    }

    pub fn with_runtime(mut self, rt: Option<Arc<Runtime>>) -> CompileRequest {
        self.runtime = rt;
        self
    }

    pub fn with_guards(mut self, guards: Vec<String>) -> CompileRequest {
        self.guards = guards;
        self
    }

    pub fn with_verbosity(mut self, v: Verbosity) -> CompileRequest {
        self.verbosity = v;
        self
    }

    pub fn with_fallback(mut self, policy: FallbackPolicy) -> CompileRequest {
        self.fallback = policy;
        self
    }
}

/// A dump artifact a [`CompiledModule`] wants written into the session's
/// dump dir at `finish()` — per-partition HLO, the compile plan, etc.
/// (Content-carrying, unlike [`crate::api::Artifact`] which records a file
/// already on disk.)
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModuleArtifact {
    pub kind: ArtifactKind,
    /// Logical name in the manifest (e.g. `__compiled_fn_1/p0`).
    pub name: String,
    /// Preferred file name inside the dump dir.
    pub file: String,
    pub content: String,
}

/// Per-module compile/runtime stats, merged into the session's
/// `metrics.json` under `"modules"`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ModuleStats {
    /// Executables this module stitches together (1 for monolithic).
    pub partitions: u64,
    /// Padded leading-dim bucket (None when not batched).
    pub bucket: Option<u64>,
    /// Inner executables served from a shared cache instead of compiled.
    pub cache_hits: u64,
}

/// An executable compiled graph: the output of [`Backend::lower`].
///
/// Beyond `call`, a module is *inspectable*: `artifacts()` returns the
/// per-partition/per-bucket dumps (plan JSON, HLO text) the session
/// indexes in `manifest.json`, and `stats()` feeds `metrics.json`.
///
/// Modules are `Send + Sync`: compile once, dispatch from any number of
/// threads (`Arc<dyn CompiledModule>` is the shared handle — see the
/// "Concurrent serving" section of the crate docs). Inputs are
/// call-local `Rc<Tensor>`s; only the module itself crosses threads.
pub trait CompiledModule: Send + Sync {
    /// Execute the module on tensor inputs shaped like the original graph.
    fn call(&self, inputs: &[Rc<Tensor>]) -> Result<Vec<Tensor>, DepyfError>;

    /// The name stamped on [`CompiledGraphFn::backend_name`].
    fn backend_name(&self) -> &str;

    /// Dump artifacts describing this module (may be empty).
    fn artifacts(&self) -> Vec<ModuleArtifact> {
        Vec::new()
    }

    fn stats(&self) -> ModuleStats {
        ModuleStats { partitions: 1, ..Default::default() }
    }

    /// Whether this module *cooperates* with a published request deadline
    /// ([`crate::serve::deadline::current_deadline`]): it bounds its own
    /// `call`, returning [`DepyfError::Timeout`] when the budget runs
    /// out. The dispatch path then skips the sidecar watchdog thread it
    /// must otherwise spawn per deadlined call — the worker is reclaimed
    /// by the module's own supervision instead of left burning CPU.
    /// Default `false`: plain synchronous executors cannot interrupt
    /// themselves.
    fn deadline_aware(&self) -> bool {
        false
    }

    /// Hook invoked by the dispatch path when `call` failed and a
    /// fallback executor served the request instead: `served_by` names
    /// the backend that actually produced `outputs`. Wrapper backends
    /// that record calls (`recording`) override this so trace bundles
    /// capture degraded calls too; the default is a no-op.
    fn record_degraded(&self, _inputs: &[Rc<Tensor>], _outputs: &[Tensor], _served_by: &str) {}
}

/// A closure-backed [`CompiledModule`] — the smallest way for custom
/// backends (and dynamo's trace/error paths) to satisfy the contract.
pub struct FnModule {
    backend_name: String,
    #[allow(clippy::type_complexity)]
    f: Box<dyn Fn(&[Rc<Tensor>]) -> Result<Vec<Tensor>, DepyfError> + Send + Sync>,
}

impl CompiledModule for FnModule {
    fn call(&self, inputs: &[Rc<Tensor>]) -> Result<Vec<Tensor>, DepyfError> {
        (self.f)(inputs)
    }

    fn backend_name(&self) -> &str {
        &self.backend_name
    }
}

/// Wrap a closure as a [`CompiledModule`].
pub fn module_from_fn(
    backend_name: impl Into<String>,
    f: impl Fn(&[Rc<Tensor>]) -> Result<Vec<Tensor>, DepyfError> + Send + Sync + 'static,
) -> Arc<dyn CompiledModule> {
    Arc::new(FnModule { backend_name: backend_name.into(), f: Box::new(f) })
}

/// A graph compiler in two explicit stages. `plan` decides *what* to build
/// (partitions, padding/bucketing, per-partition targets) as a declarative
/// [`CompilePlan`]; `lower` turns that plan into an executable
/// [`CompiledModule`]. Implementations are registered by name and looked
/// up like `torch.compile(backend="name")`.
///
/// Backends are `Send + Sync` and live in a process-wide registry:
/// compiles may be issued from any thread, so internal caches must use
/// `Mutex`/atomics rather than `RefCell`/`Cell`.
pub trait Backend: Send + Sync {
    /// Registry key and the default `backend_name` stamped on output.
    fn name(&self) -> &str;

    /// What this backend can do / needs — validated up front by
    /// `SessionBuilder::build()` and the CLI.
    fn capabilities(&self) -> Capabilities {
        Capabilities::NONE
    }

    /// True if `lower` needs `req.runtime` (derived from
    /// [`Capabilities::REQUIRES_RUNTIME`]).
    fn requires_runtime(&self) -> bool {
        self.capabilities().contains(Capabilities::REQUIRES_RUNTIME)
    }

    /// Stage 1: decide how to compile the request. The returned plan is
    /// pure description — dumpable as JSON, comparable, inspectable.
    fn plan(&self, req: &CompileRequest) -> Result<CompilePlan, DepyfError>;

    /// Stage 2: realize a plan as an executable module.
    fn lower(&self, req: &CompileRequest, plan: &CompilePlan) -> Result<Arc<dyn CompiledModule>, DepyfError>;

    /// Convenience: plan + lower in one step.
    fn compile(&self, req: &CompileRequest) -> Result<Arc<dyn CompiledModule>, DepyfError> {
        let plan = self.plan(req)?;
        self.lower(req, &plan)
    }
}

/// Build an eager-executing [`CompiledGraphFn`] with an explicit
/// `backend_name` — the reference executor and the fallback target.
/// The execution plan (topo steps, pre-materialized constants, buffer
/// liveness, reusable arena) is computed here, once per compile, not per
/// call — see [`eager::ExecPlan`]. Deliberately executes the graph
/// *verbatim* (no optimizer): the fallback is the most conservative
/// executor available, usable even when a backend choked on the
/// optimized graph.
pub fn eager_graph_fn(name: &str, graph: Arc<Graph>, backend_name: String) -> CompiledGraphFn {
    let module: Arc<dyn CompiledModule> =
        Arc::new(eager::EagerModule::with_fusion(Arc::clone(&graph), backend_name, false));
    CompiledGraphFn::from_module(name, graph, module)
}

/// Node-by-node CPU reference execution (of the optimized graph; fused
/// elementwise regions at `--opt-level 2`).
pub struct EagerBackend;

impl Backend for EagerBackend {
    fn name(&self) -> &str {
        "eager"
    }

    fn plan(&self, req: &CompileRequest) -> Result<CompilePlan, DepyfError> {
        crate::faults::gate(crate::faults::Site::BackendPlan)?;
        Ok(CompilePlan::monolithic("eager", req, "eager"))
    }

    fn lower(&self, req: &CompileRequest, _plan: &CompilePlan) -> Result<Arc<dyn CompiledModule>, DepyfError> {
        crate::faults::gate(crate::faults::Site::BackendLower)?;
        let opt = req.optimized();
        Ok(Arc::new(eager::EagerModule::with_fusion(
            Arc::clone(&opt.graph),
            "eager".into(),
            req.opt_level.fuses(),
        )))
    }
}

/// Lower to HLO text, compile + run via PJRT (fused kernels dispatched to
/// AOT Pallas artifacts when shapes match). Lowers the *optimized* graph
/// — folded/simplified but unfused: PJRT applies its own fusion, so the
/// executable cache is keyed on the optimized graph's content hash and
/// differently-captured-but-equivalent graphs share one executable.
pub struct XlaBackend;

impl Backend for XlaBackend {
    fn name(&self) -> &str {
        "xla"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities::REQUIRES_RUNTIME
    }

    fn plan(&self, req: &CompileRequest) -> Result<CompilePlan, DepyfError> {
        crate::faults::gate(crate::faults::Site::BackendPlan)?;
        Ok(CompilePlan::monolithic("xla", req, "xla"))
    }

    fn lower(&self, req: &CompileRequest, _plan: &CompilePlan) -> Result<Arc<dyn CompiledModule>, DepyfError> {
        crate::faults::gate(crate::faults::Site::BackendLower)?;
        let rt = req.runtime.as_ref().ok_or_else(|| {
            DepyfError::Backend("xla backend requires a PJRT runtime (SessionBuilder::runtime)".into())
        })?;
        let opt = req.optimized();
        Ok(Arc::new(xla::compile_module(&req.name, &opt.graph, rt)?))
    }
}

/// A compile that went through the fallback policy: the callable plus,
/// when the eager fallback engaged, the original backend error. Callers
/// use `fallback_reason` to log the degrade — never infer it from
/// `backend_name`, which custom backends are free to stamp.
#[derive(Debug)]
pub struct PolicyCompiled {
    pub f: CompiledGraphFn,
    /// `Some(reason)` iff the backend failed and [`FallbackPolicy::Eager`]
    /// substituted the eager executor.
    pub fallback_reason: Option<DepyfError>,
}

/// Drive the whole pipeline (`plan` → `lower`) through `backend`, applying
/// `req.fallback` on failure — the single implementation of the fallback
/// policy.
///
/// Under [`FallbackPolicy::Eager`] this never fails: the returned fn
/// executes eagerly, the degrade reason is returned in `fallback_reason`
/// and also recorded in `backend_name` (`"eager (xla fallback: ...)"`).
///
/// The compile runs under `catch_unwind`: a panicking backend becomes
/// [`DepyfError::Panic`] and flows through the same policy, so one bad
/// compiler never unwinds through the dispatch path (and never poisons
/// the shared locks above it). `AssertUnwindSafe` is sound here because
/// every lock the compile path touches recovers from poison instead of
/// unwrapping, and `req.opt` holds only a memoized immutable snapshot.
pub fn compile_with_policy(backend: &dyn Backend, req: &CompileRequest) -> Result<PolicyCompiled, DepyfError> {
    let compiled = catch_unwind(AssertUnwindSafe(|| backend.compile(req))).unwrap_or_else(|payload| {
        Err(DepyfError::from_panic(&format!("backend {}", backend.name()), payload))
    });
    match compiled {
        Ok(module) => Ok(PolicyCompiled {
            f: CompiledGraphFn::from_module(&req.name, Arc::clone(&req.graph), module),
            fallback_reason: None,
        }),
        Err(e) => match req.fallback {
            FallbackPolicy::Error => Err(e),
            FallbackPolicy::Eager => {
                let f = eager_graph_fn(
                    &req.name,
                    Arc::clone(&req.graph),
                    format!("eager ({} fallback: {})", backend.name(), e),
                );
                Ok(PolicyCompiled { f, fallback_reason: Some(e) })
            }
        },
    }
}

/// The process-wide backend registry. A `RwLock` so dispatch-path lookups
/// from any number of serving threads proceed in parallel and never block
/// on each other; `register_backend` writes are rare (startup, tests).
/// Lazily initialized with the builtins on first use.
static REGISTRY: OnceLock<RwLock<HashMap<String, Arc<dyn Backend>>>> = OnceLock::new();

fn registry() -> &'static RwLock<HashMap<String, Arc<dyn Backend>>> {
    REGISTRY.get_or_init(|| RwLock::new(builtin_backends()))
}

fn builtin_backends() -> HashMap<String, Arc<dyn Backend>> {
    let mut m: HashMap<String, Arc<dyn Backend>> = HashMap::new();
    m.insert("eager".into(), Arc::new(EagerBackend));
    m.insert("xla".into(), Arc::new(XlaBackend));
    m.insert("sharded".into(), Arc::new(ShardedBackend::new()));
    m.insert("batched".into(), Arc::new(BatchedBackend::new()));
    // The loop-program compiler: lowers the optimized graph to a flat,
    // register-allocated instruction buffer (see `crate::codegen`).
    m.insert("codegen".into(), Arc::new(crate::codegen::CodegenBackend::new()));
    // The default recording wrapper decorates the eager reference executor;
    // wrap any other backend via RecordingBackend::new / ::wrapping.
    m.insert("recording".into(), Arc::new(RecordingBackend::new(Arc::new(EagerBackend))));
    // The async wrapper likewise defaults to eager; `async:<name>` on the
    // CLI wraps any registered backend.
    m.insert("async".into(), Arc::new(crate::serve::AsyncBackend::new(Arc::new(EagerBackend))));
    // The sharded partition chain with one stage thread per shard.
    m.insert("pipelined".into(), Arc::new(crate::serve::PipelinedShardedBackend::new()));
    m
}

/// Register (or replace) a backend under its `name()`. Registered backends
/// are visible to [`lookup_backend`], `SessionBuilder::backend_named` and
/// the CLI's `--backend` flag. The registry is **process-wide** and
/// thread-safe: backends registered on any thread are visible to all
/// (which is why [`Backend`] is `Send + Sync`).
pub fn register_backend(backend: Arc<dyn Backend>) {
    registry()
        .write()
        .unwrap_or_else(PoisonError::into_inner)
        .insert(backend.name().to_string(), backend);
}

/// Look up a registered backend by name (`"eager"`, `"xla"`, `"sharded"`,
/// `"batched"`, `"recording"` and `"async"` are pre-registered). Takes the
/// registry read lock only — concurrent lookups never serialize.
pub fn lookup_backend(name: &str) -> Option<Arc<dyn Backend>> {
    registry().read().unwrap_or_else(PoisonError::into_inner).get(name).cloned()
}

/// All registered backend names, sorted — for usage messages and docs.
pub fn backend_names() -> Vec<String> {
    let mut v: Vec<String> =
        registry().read().unwrap_or_else(PoisonError::into_inner).keys().cloned().collect();
    v.sort();
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;
    use crate::tensor::Tensor;

    fn relu_graph() -> Arc<Graph> {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2]);
        let r = g.add_op(OpKind::Relu, vec![x]).unwrap();
        g.set_outputs(vec![r]);
        Arc::new(g)
    }

    #[test]
    fn builtins_are_registered() {
        for name in ["eager", "xla", "sharded", "batched", "codegen"] {
            assert!(lookup_backend(name).is_some(), "{} missing", name);
        }
        assert!(lookup_backend("missing").is_none());
        let names = backend_names();
        assert!(names.contains(&"sharded".to_string()) && names.contains(&"batched".to_string()));
    }

    #[test]
    fn capability_bitset_semantics() {
        let caps = Capabilities::PARTITION | Capabilities::USES_RUNTIME;
        assert!(caps.contains(Capabilities::PARTITION));
        assert!(!caps.contains(Capabilities::DYNAMIC_BATCH));
        assert_eq!(caps.missing(Capabilities::PARTITION), Capabilities::NONE);
        assert_eq!(
            caps.missing(Capabilities::DYNAMIC_BATCH | Capabilities::PARTITION),
            Capabilities::DYNAMIC_BATCH
        );
        assert_eq!(format!("{}", Capabilities::DYNAMIC_BATCH), "dynamic_batch");
        assert_eq!(format!("{}", Capabilities::NONE), "none");
        assert!(XlaBackend.requires_runtime());
        assert!(!EagerBackend.requires_runtime());
    }

    #[test]
    fn request_derives_specs_and_cache_key() {
        let g = relu_graph();
        let req = CompileRequest::new("g", Arc::clone(&g));
        assert_eq!(req.cache_key, g.content_hash());
        assert_eq!(req.input_specs, vec![InputSpec { name: "x".into(), shape: vec![2] }]);
        assert!(req.guards.is_empty() && req.runtime.is_none());
    }

    #[test]
    fn custom_backend_registration_round_trip() {
        struct Doubler;
        impl Backend for Doubler {
            fn name(&self) -> &str {
                "doubler-test"
            }
            fn plan(&self, req: &CompileRequest) -> Result<CompilePlan, DepyfError> {
                Ok(CompilePlan::monolithic("doubler-test", req, "eager"))
            }
            fn lower(
                &self,
                req: &CompileRequest,
                _plan: &CompilePlan,
            ) -> Result<Arc<dyn CompiledModule>, DepyfError> {
                Ok(Arc::new(eager::EagerModule::with_name(Arc::clone(&req.graph), "doubler-test".into())))
            }
        }
        register_backend(Arc::new(Doubler));
        let b = lookup_backend("doubler-test").expect("registered");
        assert_eq!(b.name(), "doubler-test");
        assert!(!b.requires_runtime());
        let req = CompileRequest::new("g", relu_graph());
        let plan = b.plan(&req).unwrap();
        assert_eq!(plan.partitions.len(), 1);
        let module = b.lower(&req, &plan).unwrap();
        assert_eq!(module.backend_name(), "doubler-test");
        let out = module.call(&[Rc::new(Tensor::new(vec![2], vec![-1.0, 2.0]))]).unwrap();
        assert_eq!(out[0].data(), &[0.0, 2.0]);
    }

    #[test]
    fn xla_without_runtime_errors_under_error_policy() {
        let req = CompileRequest::new("g", relu_graph()).with_fallback(FallbackPolicy::Error);
        let err = compile_with_policy(&XlaBackend, &req).unwrap_err();
        assert_eq!(err.layer(), "backend");
        assert!(err.to_string().contains("runtime"), "{}", err);
    }

    #[test]
    fn xla_without_runtime_degrades_under_eager_policy() {
        let req = CompileRequest::new("g", relu_graph());
        let pc = compile_with_policy(&XlaBackend, &req).unwrap();
        assert!(pc.fallback_reason.is_some(), "degrade must be signalled explicitly");
        assert!(pc.f.backend_name.starts_with("eager (xla fallback:"), "{}", pc.f.backend_name);
        let out = pc.f.call(&[Rc::new(Tensor::new(vec![2], vec![-3.0, 3.0]))]).unwrap();
        assert_eq!(out[0].data(), &[0.0, 3.0]);
    }

    #[test]
    fn successful_custom_backend_reports_no_fallback() {
        struct Tagger;
        impl Backend for Tagger {
            fn name(&self) -> &str {
                "tagger"
            }
            fn plan(&self, req: &CompileRequest) -> Result<CompilePlan, DepyfError> {
                Ok(CompilePlan::monolithic("tagger", req, "eager"))
            }
            fn lower(
                &self,
                req: &CompileRequest,
                _plan: &CompilePlan,
            ) -> Result<Arc<dyn CompiledModule>, DepyfError> {
                Ok(Arc::new(eager::EagerModule::with_name(Arc::clone(&req.graph), "tagger-v2".into())))
            }
        }
        let pc = compile_with_policy(&Tagger, &CompileRequest::new("g", relu_graph())).unwrap();
        // A custom backend_name differing from name() is NOT a fallback.
        assert!(pc.fallback_reason.is_none());
        assert_eq!(pc.f.backend_name, "tagger-v2");
    }

    #[test]
    fn panicking_backend_is_isolated_and_degrades() {
        struct Bomb;
        impl Backend for Bomb {
            fn name(&self) -> &str {
                "bomb"
            }
            fn plan(&self, _req: &CompileRequest) -> Result<CompilePlan, DepyfError> {
                panic!("kaboom")
            }
            fn lower(
                &self,
                _req: &CompileRequest,
                _plan: &CompilePlan,
            ) -> Result<Arc<dyn CompiledModule>, DepyfError> {
                unreachable!("plan always panics")
            }
        }
        // Error policy surfaces the panic as a typed, transient error.
        let req = CompileRequest::new("g", relu_graph()).with_fallback(FallbackPolicy::Error);
        let err = compile_with_policy(&Bomb, &req).unwrap_err();
        assert_eq!(err.layer(), "panic");
        assert!(err.to_string().contains("backend bomb panicked: kaboom"), "{}", err);
        assert!(err.is_transient());
        // Eager policy degrades and the result still executes.
        let req = CompileRequest::new("g", relu_graph());
        let pc = compile_with_policy(&Bomb, &req).unwrap();
        assert!(pc.fallback_reason.is_some(), "panic degrade must be signalled");
        assert!(pc.f.backend_name.starts_with("eager (bomb fallback:"), "{}", pc.f.backend_name);
        let out = pc.f.call(&[Rc::new(Tensor::new(vec![2], vec![-1.0, 4.0]))]).unwrap();
        assert_eq!(out[0].data(), &[0.0, 4.0]);
    }

    #[test]
    fn fn_module_wraps_closures() {
        let m = module_from_fn("custom", |inputs| Ok(vec![(*inputs[0]).clone()]));
        assert_eq!(m.backend_name(), "custom");
        assert!(m.artifacts().is_empty());
        assert_eq!(m.stats().partitions, 1);
        let out = m.call(&[Rc::new(Tensor::scalar(5.0))]).unwrap();
        assert_eq!(out[0].item(), 5.0);
    }
}
