//! The pluggable [`Backend`] trait, the process-wide backend registry, and
//! the explicit [`FallbackPolicy`] — the analogue of
//! `torch.compile(backend=...)` accepting both built-in names and custom
//! callables.
//!
//! `Eager` and `Xla` are just two implementations registered by default;
//! [`register_backend`] lets users plug their own compiler into dynamo and
//! [`crate::api::SessionBuilder`] without touching this crate.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::backend::{eager, xla};
use crate::graph::{CompiledGraphFn, Graph};
use crate::runtime::Runtime;

use super::error::DepyfError;

/// What dynamo does when a backend fails to compile a captured graph.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FallbackPolicy {
    /// Degrade to the eager reference executor (how torch.compile backends
    /// behave); the reason is recorded in the compiled fn's `backend_name`
    /// and in the frontend log — never silently.
    #[default]
    Eager,
    /// Propagate the backend error instead of degrading.
    Error,
}

/// Everything a backend may need at compile time.
#[derive(Clone, Default)]
pub struct CompileCtx {
    /// PJRT runtime, for backends that lower to HLO.
    pub runtime: Option<Rc<Runtime>>,
    /// Applied by the caller driving [`compile_with_policy`] (dynamo, the
    /// legacy shim) — backends themselves must NOT apply it; they report
    /// failures and let the policy decide.
    pub fallback: FallbackPolicy,
}

/// A graph compiler: turns a captured [`Graph`] into a callable
/// [`CompiledGraphFn`]. Implementations are registered by name and looked
/// up like `torch.compile(backend="name")`.
pub trait Backend {
    /// Registry key and the default `backend_name` stamped on output.
    fn name(&self) -> &str;

    /// True if `compile` needs `ctx.runtime`. `SessionBuilder::build()`
    /// uses this to reject misconfiguration up front under
    /// [`FallbackPolicy::Error`].
    fn requires_runtime(&self) -> bool {
        false
    }

    /// Compile one captured graph.
    fn compile(&self, name: &str, graph: Rc<Graph>, ctx: &CompileCtx) -> Result<CompiledGraphFn, DepyfError>;
}

/// Build an eager-executing [`CompiledGraphFn`] with an explicit
/// `backend_name` — the reference executor and the fallback target.
/// The execution plan (topo steps, pre-materialized constants, buffer
/// liveness, reusable arena) is computed here, once per compile, not per
/// call — see [`eager::ExecPlan`].
pub fn eager_graph_fn(name: &str, graph: Rc<Graph>, backend_name: String) -> CompiledGraphFn {
    let plan = eager::ExecPlan::new(Rc::clone(&graph));
    CompiledGraphFn {
        name: name.to_string(),
        graph,
        backend_name,
        executor: Box::new(move |inputs| plan.run(inputs)),
        calls: std::cell::Cell::new(0),
    }
}

/// Node-by-node CPU reference execution.
pub struct EagerBackend;

impl Backend for EagerBackend {
    fn name(&self) -> &str {
        "eager"
    }

    fn compile(&self, name: &str, graph: Rc<Graph>, _ctx: &CompileCtx) -> Result<CompiledGraphFn, DepyfError> {
        Ok(eager_graph_fn(name, graph, "eager".into()))
    }
}

/// Lower to HLO text, compile + run via PJRT (fused kernels dispatched to
/// AOT Pallas artifacts when shapes match).
pub struct XlaBackend;

impl Backend for XlaBackend {
    fn name(&self) -> &str {
        "xla"
    }

    fn requires_runtime(&self) -> bool {
        true
    }

    fn compile(&self, name: &str, graph: Rc<Graph>, ctx: &CompileCtx) -> Result<CompiledGraphFn, DepyfError> {
        let rt = ctx.runtime.as_ref().ok_or_else(|| {
            DepyfError::Backend("xla backend requires a PJRT runtime (SessionBuilder::runtime)".into())
        })?;
        xla::compile(name, &graph, rt)
    }
}

/// A compile that went through the fallback policy: the callable plus,
/// when the eager fallback engaged, the original backend error. Callers
/// use `fallback_reason` to log the degrade — never infer it from
/// `backend_name`, which custom backends are free to stamp.
#[derive(Debug)]
pub struct PolicyCompiled {
    pub f: CompiledGraphFn,
    /// `Some(reason)` iff the backend failed and [`FallbackPolicy::Eager`]
    /// substituted the eager executor.
    pub fallback_reason: Option<DepyfError>,
}

/// Compile through `backend`, applying `ctx.fallback` on failure — the
/// single implementation of the fallback policy.
///
/// Under [`FallbackPolicy::Eager`] this never fails: the returned fn
/// executes eagerly, the degrade reason is returned in `fallback_reason`
/// and also recorded in `backend_name` (`"eager (xla fallback: ...)"`).
pub fn compile_with_policy(
    backend: &dyn Backend,
    name: &str,
    graph: Rc<Graph>,
    ctx: &CompileCtx,
) -> Result<PolicyCompiled, DepyfError> {
    match backend.compile(name, Rc::clone(&graph), ctx) {
        Ok(f) => Ok(PolicyCompiled { f, fallback_reason: None }),
        Err(e) => match ctx.fallback {
            FallbackPolicy::Error => Err(e),
            FallbackPolicy::Eager => {
                let f = eager_graph_fn(name, graph, format!("eager ({} fallback: {})", backend.name(), e));
                Ok(PolicyCompiled { f, fallback_reason: Some(e) })
            }
        },
    }
}

thread_local! {
    static REGISTRY: RefCell<HashMap<String, Rc<dyn Backend>>> = RefCell::new(builtin_backends());
}

fn builtin_backends() -> HashMap<String, Rc<dyn Backend>> {
    let mut m: HashMap<String, Rc<dyn Backend>> = HashMap::new();
    m.insert("eager".into(), Rc::new(EagerBackend));
    m.insert("xla".into(), Rc::new(XlaBackend));
    m
}

/// Register (or replace) a backend under its `name()`. Registered backends
/// are visible to [`lookup_backend`], `SessionBuilder::backend_named` and
/// the CLI's `--backend` flag. The registry is per-thread (the whole stack
/// is `Rc`-based and single-threaded).
pub fn register_backend(backend: Rc<dyn Backend>) {
    REGISTRY.with(|r| {
        r.borrow_mut().insert(backend.name().to_string(), backend);
    });
}

/// Look up a registered backend by name (`"eager"` and `"xla"` are
/// pre-registered).
pub fn lookup_backend(name: &str) -> Option<Rc<dyn Backend>> {
    REGISTRY.with(|r| r.borrow().get(name).cloned())
}

/// All registered backend names, sorted — for usage messages and docs.
pub fn backend_names() -> Vec<String> {
    REGISTRY.with(|r| {
        let mut v: Vec<String> = r.borrow().keys().cloned().collect();
        v.sort();
        v
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;
    use crate::tensor::Tensor;

    fn relu_graph() -> Rc<Graph> {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2]);
        let r = g.add_op(OpKind::Relu, vec![x]).unwrap();
        g.set_outputs(vec![r]);
        Rc::new(g)
    }

    #[test]
    fn builtins_are_registered() {
        assert!(lookup_backend("eager").is_some());
        assert!(lookup_backend("xla").is_some());
        assert!(lookup_backend("missing").is_none());
        let names = backend_names();
        assert!(names.contains(&"eager".to_string()) && names.contains(&"xla".to_string()));
    }

    #[test]
    fn custom_backend_registration_round_trip() {
        struct Doubler;
        impl Backend for Doubler {
            fn name(&self) -> &str {
                "doubler-test"
            }
            fn compile(
                &self,
                name: &str,
                graph: Rc<Graph>,
                _ctx: &CompileCtx,
            ) -> Result<CompiledGraphFn, DepyfError> {
                Ok(eager_graph_fn(name, graph, "doubler-test".into()))
            }
        }
        register_backend(Rc::new(Doubler));
        let b = lookup_backend("doubler-test").expect("registered");
        assert_eq!(b.name(), "doubler-test");
        assert!(!b.requires_runtime());
        let f = b.compile("g", relu_graph(), &CompileCtx::default()).unwrap();
        assert_eq!(f.backend_name, "doubler-test");
        let out = f.call(&[Rc::new(Tensor::new(vec![2], vec![-1.0, 2.0]))]).unwrap();
        assert_eq!(out[0].data(), &[0.0, 2.0]);
    }

    #[test]
    fn xla_without_runtime_errors_under_error_policy() {
        let ctx = CompileCtx { runtime: None, fallback: FallbackPolicy::Error };
        let err = compile_with_policy(&XlaBackend, "g", relu_graph(), &ctx).unwrap_err();
        assert_eq!(err.layer(), "backend");
        assert!(err.to_string().contains("runtime"), "{}", err);
    }

    #[test]
    fn xla_without_runtime_degrades_under_eager_policy() {
        let ctx = CompileCtx { runtime: None, fallback: FallbackPolicy::Eager };
        let pc = compile_with_policy(&XlaBackend, "g", relu_graph(), &ctx).unwrap();
        assert!(pc.fallback_reason.is_some(), "degrade must be signalled explicitly");
        assert!(pc.f.backend_name.starts_with("eager (xla fallback:"), "{}", pc.f.backend_name);
        let out = pc.f.call(&[Rc::new(Tensor::new(vec![2], vec![-3.0, 3.0]))]).unwrap();
        assert_eq!(out[0].data(), &[0.0, 3.0]);
    }

    #[test]
    fn successful_custom_backend_reports_no_fallback() {
        struct Tagger;
        impl Backend for Tagger {
            fn name(&self) -> &str {
                "tagger"
            }
            fn compile(
                &self,
                name: &str,
                graph: Rc<Graph>,
                _ctx: &CompileCtx,
            ) -> Result<CompiledGraphFn, DepyfError> {
                Ok(eager_graph_fn(name, graph, "tagger-v2".into()))
            }
        }
        let pc = compile_with_policy(&Tagger, "g", relu_graph(), &CompileCtx::default()).unwrap();
        // A custom backend_name differing from name() is NOT a fallback.
        assert!(pc.fallback_reason.is_none());
        assert_eq!(pc.f.backend_name, "tagger-v2");
    }
}
