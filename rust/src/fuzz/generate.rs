//! Seeded program generation from composable templates.
//!
//! Every random choice flows through one [`Rng`] seeded per iteration, so
//! `(seed, iter)` fully determines the program — the property the CI
//! fuzz-smoke job and `--seed`-based repro both rely on.

use crate::tensor::Rng;

use super::prog::{CallSite, ExitKind, Expr, Frag, Helper, HelperKind, LoopExit, Prog};

/// Zero-arg tensor methods safe for any shape and bounded on `[0, 1)`-ish
/// inputs (no NaN/inf producers — see [`Expr`] docs).
pub const METHODS: &[&str] = &["relu", "gelu", "tanh", "sigmoid", "abs", "neg", "softmax"];

/// `torch.<name>(x)` unary builtins captured as graph ops.
pub const TORCH_UNARY: &[&str] = &["relu", "gelu", "tanh", "softmax"];

/// Float literals used by [`Expr::AddFloat`] (exactly representable, so
/// rendering and re-parsing round-trip bit-exactly).
pub const FLOATS: &[&str] = &["0.5", "0.25", "1.5"];

/// Call-site shapes: 1-D and 2-D, all small. Shape diversity across call
/// sites is what exercises guard specialization and recompiles.
pub const SHAPES: &[&[usize]] = &[&[4], &[8], &[2, 3], &[3, 2], &[6], &[2, 2]];

fn pick<'a, T>(rng: &mut Rng, xs: &'a [T]) -> &'a T {
    &xs[rng.below(xs.len())]
}

/// Build a random tensor expression over the variables in scope.
fn gen_expr(rng: &mut Rng, tensors: &[String], scalars: &[String], helpers: &[Helper], depth: usize) -> Expr {
    if depth == 0 {
        return Expr::Var(pick(rng, tensors).clone());
    }
    match rng.below(8) {
        0 => {
            let op = *pick(rng, &['+', '-', '*']);
            let a = gen_expr(rng, tensors, scalars, helpers, depth - 1);
            let b = gen_expr(rng, tensors, scalars, helpers, depth - 1);
            Expr::Bin(op, Box::new(a), Box::new(b))
        }
        1 => Expr::Method(pick(rng, METHODS).to_string(), Box::new(gen_expr(rng, tensors, scalars, helpers, depth - 1))),
        2 => Expr::Torch(pick(rng, TORCH_UNARY).to_string(), Box::new(gen_expr(rng, tensors, scalars, helpers, depth - 1))),
        3 => Expr::ScaleInt(Box::new(gen_expr(rng, tensors, scalars, helpers, depth - 1)), 1 + rng.below(4) as i64),
        4 => Expr::AddFloat(Box::new(gen_expr(rng, tensors, scalars, helpers, depth - 1)), pick(rng, FLOATS).to_string()),
        5 if !scalars.is_empty() => {
            Expr::ScaleVar(Box::new(gen_expr(rng, tensors, scalars, helpers, depth - 1)), pick(rng, scalars).clone())
        }
        6 if !helpers.is_empty() => {
            let h = pick(rng, helpers).name.clone();
            Expr::Call(h, Box::new(gen_expr(rng, tensors, scalars, helpers, depth - 1)))
        }
        _ => Expr::Var(pick(rng, tensors).clone()),
    }
}

fn gen_exit(rng: &mut Rng, n: i64) -> Option<LoopExit> {
    match rng.below(3) {
        0 => None,
        1 => Some(LoopExit { when: rng.below(n.max(1) as usize) as i64, kind: ExitKind::Break }),
        _ => Some(LoopExit { when: rng.below(n.max(1) as usize) as i64, kind: ExitKind::Continue }),
    }
}

/// Generate a fresh program. All names are positional (`t0`, `s0`, `i0`,
/// ...), so two structurally equal programs render to identical source.
pub fn generate(rng: &mut Rng) -> Prog {
    let mut helpers = Vec::new();
    if rng.below(2) == 0 {
        helpers.push(Helper { name: "h0".into(), kind: HelperKind::Plain { k: 1 + rng.below(4) as i64 } });
    }
    if rng.below(3) == 0 {
        helpers.push(Helper { name: "g0".into(), kind: HelperKind::Closure { k: 1 + rng.below(3) as i64 } });
    }

    let mut tensors: Vec<String> = vec!["x".into()];
    let mut scalars: Vec<String> = Vec::new();
    let mut body: Vec<Frag> = Vec::new();
    let mut next_t = 0usize;
    let mut next_s = 0usize;
    let mut next_loop = 0usize;
    let mut next_list = 0usize;

    let nfrags = 2 + rng.below(3);
    for _ in 0..nfrags {
        let dst = format!("t{}", next_t);
        next_t += 1;
        let frag = match rng.below(7) {
            0 | 1 => Frag::Assign { dst: dst.clone(), expr: gen_expr(rng, &tensors, &scalars, &helpers, 2) },
            2 => {
                // Scalar definition + immediate tensor use (mixed int/float
                // arithmetic feeding tensor ops).
                let s = format!("s{}", next_s);
                next_s += 1;
                let text = match rng.below(4) {
                    0 => "(2 + 1)".to_string(),
                    1 => "(3 * 2)".to_string(),
                    2 => "(5 - 3)".to_string(),
                    _ => format!("{}", 1 + rng.below(4)),
                };
                scalars.push(s.clone());
                let inner = gen_expr(rng, &tensors, &scalars, &helpers, 1);
                body.push(Frag::Scalar { dst: s.clone(), text });
                Frag::Assign { dst: dst.clone(), expr: Expr::ScaleVar(Box::new(inner), s) }
            }
            3 => Frag::Branch {
                dst: dst.clone(),
                recv: pick(rng, &tensors).clone(),
                via_item: rng.below(2) == 0,
                thr: rng.below(6) as i64,
                then_expr: gen_expr(rng, &tensors, &scalars, &helpers, 1),
                else_expr: gen_expr(rng, &tensors, &scalars, &helpers, 1),
            },
            4 => {
                let var = format!("i{}", next_loop);
                next_loop += 1;
                let n = 2 + rng.below(4) as i64;
                Frag::ForLoop {
                    var,
                    n,
                    acc: dst.clone(),
                    init: gen_expr(rng, &tensors, &scalars, &helpers, 1),
                    step: gen_expr(rng, &tensors, &scalars, &helpers, 1),
                    exit: gen_exit(rng, n),
                }
            }
            5 => {
                let counter = format!("c{}", next_loop);
                next_loop += 1;
                let start = 2 + rng.below(4) as i64;
                Frag::WhileLoop {
                    counter,
                    start,
                    acc: dst.clone(),
                    init: gen_expr(rng, &tensors, &scalars, &helpers, 1),
                    step: gen_expr(rng, &tensors, &scalars, &helpers, 1),
                    exit: gen_exit(rng, start),
                }
            }
            _ => {
                let list = format!("xs{}", next_list);
                next_list += 1;
                let n_items = 2 + rng.below(2);
                let items = (0..n_items).map(|_| gen_expr(rng, &tensors, &scalars, &helpers, 1)).collect();
                Frag::ListSum { list, dst: dst.clone(), items }
            }
        };
        body.push(frag);
        tensors.push(dst);
    }

    let ret = tensors.last().cloned().unwrap_or_else(|| "x".into());

    let mut calls = Vec::new();
    let n_calls = 1 + rng.below(3);
    for i in 0..n_calls {
        let shape: Vec<usize> = if i > 0 && rng.below(3) == 0 {
            // Repeat the previous shape: guard-cache hit path.
            calls[i - 1].shape.clone()
        } else {
            pick(rng, SHAPES).to_vec()
        };
        calls.push(CallSite { shape });
    }

    Prog { helpers, body, ret, calls }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bytecode::IsaVersion;

    #[test]
    fn generated_programs_are_deterministic() {
        for seed in 0..10u64 {
            let a = generate(&mut Rng::new(seed)).render();
            let b = generate(&mut Rng::new(seed)).render();
            assert_eq!(a, b, "seed {}", seed);
        }
    }

    #[test]
    fn generated_programs_compile_and_run_on_the_plain_vm() {
        for seed in 0..60u64 {
            let src = generate(&mut Rng::new(seed)).render();
            crate::pylang::compile_module(&src, "<fuzz>", IsaVersion::V310)
                .unwrap_or_else(|e| panic!("seed {}: {}\n{}", seed, e, src));
            let vm = crate::vm::Vm::new();
            vm.seed(7);
            vm.instr_budget.set(500_000);
            vm.exec_source(&src, IsaVersion::V310)
                .unwrap_or_else(|e| panic!("seed {}: {}\n{}", seed, e, src));
            assert!(!vm.take_output().is_empty(), "seed {} printed nothing:\n{}", seed, src);
        }
    }
}
