//! Program-level differential fuzzing (`depyf fuzz`).
//!
//! The conformance harness sweeps *graphs*; TorchProbe-style experience
//! says dynamic-compiler bugs concentrate higher up — in capture, guards
//! and control flow. This module closes that gap: it generates whole
//! `pylang` programs from composable templates (data-dependent branches,
//! `for`/`while` loops with `break`/`continue`, closures, container
//! mutation, tensor-shape changes across guard boundaries, mixed
//! int/float/bool arithmetic), applies semantics-preserving and
//! semantics-perturbing mutations, and runs each program twice — once on
//! the plain VM, once under dynamo — diffing printed output, error
//! messages and result **bit patterns** across backends and opt levels.
//!
//! Pipeline per iteration (fully determined by `(seed, iter)`; no
//! wall-clock anywhere):
//!
//! 1. [`generate`](generate::generate) a program, [`mutate`](mutate::mutate) it;
//! 2. run it plain ([`oracle::run_program`]) — instruction-budget
//!    exhaustion skips the iteration;
//! 3. for each backend × opt level, run hooked and [`oracle::compare`];
//! 4. on divergence, [`shrink`](shrink::shrink) the program while the same
//!    failure kind reproduces, chain into the `replay` single-op localizer
//!    ([`localize_source`]), and emit a [`FuzzBundle`] — the committed
//!    regression format replayed by `tests/fuzz_regressions.rs`.
//!
//! Panics on either side are caught under `catch_unwind` and are always
//! findings: the user-input-reachable panics this fuzzer tripped first
//! (capture unary-op unwrap, compiler loop-stack unwraps, builtin shape
//! wraparound) are now typed errors or graceful graph breaks, each pinned
//! by a committed bundle.

pub mod bundle;
pub mod generate;
pub mod mutate;
pub mod oracle;
pub mod prog;
pub mod shrink;

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

use crate::api::{lookup_backend, ArtifactKind, Backend, TraceBundle};
use crate::backend::{replay_bundle, RecordingBackend, ReplayOptions, ResilientBackend};
use crate::bytecode::IsaVersion;
use crate::dynamo::{Dynamo, DynamoConfig, Verbosity};
use crate::graph::opt::OptLevel;
use crate::tensor::Rng;
use crate::vm::Vm;

pub use bundle::FuzzBundle;
pub use oracle::{compare, run_program, DivergenceKind, RunOutcome, RunStatus};

/// Default per-run instruction budget. Loops the generator emits are
/// bounded, so a trip means a mutation produced something pathological —
/// the iteration is skipped, not reported.
pub const DEFAULT_BUDGET: u64 = 500_000;

/// Backends every default fuzz run sweeps: all registered graph compilers
/// plus a wrapper composition. `async` is deliberately not in the default
/// set — its worker threads are exercised by `tests/chaos.rs`, and the
/// oracle wants single-threaded determinism; select it explicitly with
/// `--backend async:<inner>` if wanted.
pub fn default_backends() -> Vec<String> {
    vec![
        "eager".to_string(),
        "sharded".to_string(),
        "batched".to_string(),
        "codegen".to_string(),
        "resilient:codegen".to_string(),
    ]
}

/// Resolve a backend name, honouring the CLI wrapper grammar
/// (`recording:<inner>`, `resilient[:<inner>]`).
pub fn resolve_backend(name: &str) -> Result<Arc<dyn Backend>, String> {
    if let Some(inner) = name.strip_prefix("recording:") {
        return RecordingBackend::wrapping(inner).map(|b| Arc::new(b) as Arc<dyn Backend>).map_err(|e| e.to_string());
    }
    if let Some(inner) = name.strip_prefix("async:") {
        return crate::serve::AsyncBackend::wrapping(inner)
            .map(|b| Arc::new(b) as Arc<dyn Backend>)
            .map_err(|e| e.to_string());
    }
    if name == "resilient" || name.starts_with("resilient:") {
        let inner = name.strip_prefix("resilient:").unwrap_or("eager");
        return ResilientBackend::wrapping(inner).map(|b| Arc::new(b) as Arc<dyn Backend>).map_err(|e| e.to_string());
    }
    lookup_backend(name).ok_or_else(|| format!("unknown backend '{}'", name))
}

/// Options for one [`run_fuzz`] sweep.
#[derive(Clone, Debug)]
pub struct FuzzOptions {
    pub seed: u64,
    pub iters: u64,
    /// Backend names to sweep (empty: [`default_backends`]).
    pub backends: Vec<String>,
    /// Opt levels to sweep (empty: `[O0, O2]`).
    pub opt_levels: Vec<OptLevel>,
    /// Per-run instruction budget.
    pub budget: u64,
    /// Delta-debug failures before bundling (disable for speed when
    /// triaging interactively).
    pub shrink: bool,
    /// `--serve --threads N`: dispatch the hooked runs from N concurrent
    /// threads through one shared [`crate::serve::ModuleCache`] per
    /// backend, diffing each against the precomputed single-thread plain
    /// outcome. Divergences are not shrunk (re-running a shrink candidate
    /// single-threaded cannot reproduce a concurrency bug).
    pub serve_threads: Option<usize>,
    /// `--bisect-opt`: re-run each (shrunken) divergence at O0/O1/O2 and
    /// record the first exhibiting level in the bundle.
    pub bisect_opt: bool,
}

impl Default for FuzzOptions {
    fn default() -> Self {
        FuzzOptions {
            seed: 42,
            iters: 100,
            backends: Vec::new(),
            opt_levels: Vec::new(),
            budget: DEFAULT_BUDGET,
            shrink: true,
            serve_threads: None,
            bisect_opt: false,
        }
    }
}

/// Outcome of a sweep.
#[derive(Clone, Debug, Default)]
pub struct FuzzReport {
    pub seed: u64,
    pub iters: u64,
    /// Differential runs performed (programs × backends × opt levels).
    pub runs: u64,
    /// Iterations skipped because a side tripped the instruction budget.
    pub skipped_budget: u64,
    pub failures: Vec<FuzzBundle>,
}

impl FuzzReport {
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "fuzz: seed {} — {} program(s), {} differential run(s), {} budget skip(s), {} failure(s)",
            self.seed,
            self.iters,
            self.runs,
            self.skipped_budget,
            self.failures.len()
        );
        for f in &self.failures {
            out.push_str(&format!(
                "\n  {}: {} on {} at O{} (iter {})",
                f.name, f.kind, f.backend, f.opt_level, f.iter
            ));
            if let Some(c) = &f.culprit {
                for line in c.lines() {
                    out.push_str(&format!("\n    {}", line));
                }
            }
        }
        out
    }
}

/// Per-iteration RNG: decorrelates consecutive iterations without any
/// global state (same scheme as the guard-cache hashers: golden-ratio odd
/// multiplier).
fn iter_rng(seed: u64, iter: u64) -> Rng {
    Rng::new(seed ^ iter.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(0xD1B54A32D192ED03))
}

/// The program source for `(seed, iter)` — the repro coordinates printed
/// in reports and stored in bundles.
pub fn gen_source(seed: u64, iter: u64) -> String {
    let mut rng = iter_rng(seed, iter);
    let mut prog = generate::generate(&mut rng);
    mutate::mutate(&mut prog, &mut rng);
    prog.render()
}

/// Chain a shrunken output divergence into the existing `replay` single-op
/// localizer: re-run the program with a recording wrapper around the
/// target backend, then replay every captured trace bundle against the
/// eager oracle with per-op localization. Returns the rendered replay
/// report(s) for bundles that still mismatch, if any.
pub fn localize_source(src: &str, backend_name: &str, opt: OptLevel, budget: u64) -> Option<String> {
    let backend = resolve_backend(backend_name).ok()?;
    let src = src.to_string();
    let result = catch_unwind(AssertUnwindSafe(move || {
        let rec: Arc<dyn Backend> = Arc::new(RecordingBackend::new(Arc::clone(&backend)));
        let mut vm = Vm::new();
        vm.seed(oracle::ORACLE_SEED);
        vm.instr_budget.set(budget);
        let dynamo = Dynamo::new(DynamoConfig {
            backend: rec,
            opt_level: opt,
            verbosity: Verbosity::Quiet,
            ..Default::default()
        });
        vm.eval_hook = Some(dynamo.clone());
        let _ = vm.exec_source(&src, IsaVersion::V310);
        let mut notes = Vec::new();
        for cf in dynamo.compiled() {
            for art in cf.module.artifacts() {
                if art.kind != ArtifactKind::Trace {
                    continue;
                }
                let Ok(tb) = TraceBundle::parse(&art.content) else { continue };
                let opts = ReplayOptions { localize: true, opt_level: opt, ..Default::default() };
                match replay_bundle(&tb, backend.as_ref(), Some(&crate::api::EagerBackend), &opts) {
                    Ok(report) if !report.ok() => notes.push(report.render()),
                    _ => {}
                }
            }
        }
        notes
    }));
    match result {
        Ok(notes) if !notes.is_empty() => Some(notes.join("\n")),
        _ => None,
    }
}

/// Re-run a divergent source at O0/O1/O2 on the same backend and report
/// the first level the divergence exhibits at — the `--bisect-opt`
/// triage step that separates "the optimizer broke it" (first level 1
/// or 2) from "capture/codegen broke it" (level 0). `None` when the
/// divergence does not reproduce single-threaded at any level.
pub fn bisect_first_divergent_opt(src: &str, backend: &Arc<dyn Backend>, budget: u64) -> Option<u8> {
    let plain = run_program(src, None, budget);
    if plain.status == RunStatus::Budget {
        return None;
    }
    for opt in [OptLevel::O0, OptLevel::O1, OptLevel::O2] {
        let hooked = run_program(src, Some((Arc::clone(backend), opt)), budget);
        if hooked.status == RunStatus::Budget {
            continue;
        }
        if compare(&plain, &hooked).is_some() {
            return Some(opt.as_u8());
        }
    }
    None
}

/// Run a full differential sweep. Deterministic in `opts`: same options,
/// same report (counts, failure names, sources, bundles). With
/// [`FuzzOptions::serve_threads`] set, dispatch runs in concurrent serve
/// mode instead ([`run_fuzz_serve`]).
pub fn run_fuzz(opts: &FuzzOptions) -> Result<FuzzReport, String> {
    if let Some(threads) = opts.serve_threads {
        return run_fuzz_serve(opts, threads.max(1));
    }
    let backend_names = if opts.backends.is_empty() { default_backends() } else { opts.backends.clone() };
    let mut backends: Vec<(String, Arc<dyn Backend>)> = Vec::new();
    for name in &backend_names {
        backends.push((name.clone(), resolve_backend(name)?));
    }
    let opt_levels: Vec<OptLevel> =
        if opts.opt_levels.is_empty() { vec![OptLevel::O0, OptLevel::O2] } else { opts.opt_levels.clone() };

    let mut report =
        FuzzReport { seed: opts.seed, iters: opts.iters, runs: 0, skipped_budget: 0, failures: Vec::new() };

    for iter in 0..opts.iters {
        let mut rng = iter_rng(opts.seed, iter);
        let mut prog = generate::generate(&mut rng);
        mutate::mutate(&mut prog, &mut rng);
        let src = prog.render();

        let plain = run_program(&src, None, opts.budget);
        if plain.status == RunStatus::Budget {
            report.skipped_budget += 1;
            continue;
        }

        'combos: for (name, backend) in &backends {
            for &opt in &opt_levels {
                report.runs += 1;
                let hooked = run_program(&src, Some((Arc::clone(backend), opt)), opts.budget);
                if hooked.status == RunStatus::Budget {
                    report.skipped_budget += 1;
                    continue;
                }
                let Some(kind) = compare(&plain, &hooked) else { continue };

                // Shrink while the same failure kind reproduces on the
                // same backend × opt level.
                let final_prog = if opts.shrink {
                    let backend = Arc::clone(backend);
                    let budget = opts.budget;
                    shrink::shrink(
                        &prog,
                        &mut |cand| {
                            let s = cand.render();
                            let p = run_program(&s, None, budget);
                            if p.status == RunStatus::Budget {
                                return false;
                            }
                            let h = run_program(&s, Some((Arc::clone(&backend), opt)), budget);
                            compare(&p, &h) == Some(kind)
                        },
                        200,
                    )
                } else {
                    prog.clone()
                };
                let final_src = final_prog.render();
                let final_plain = run_program(&final_src, None, opts.budget);
                let final_hooked = run_program(&final_src, Some((Arc::clone(backend), opt)), opts.budget);

                let culprit = if kind == DivergenceKind::Output {
                    localize_source(&final_src, name, opt, opts.budget)
                } else {
                    None
                };
                let first_divergent_opt = if opts.bisect_opt {
                    bisect_first_divergent_opt(&final_src, backend, opts.budget)
                } else {
                    None
                };
                let safe_name: String =
                    name.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '_' }).collect();
                report.failures.push(FuzzBundle {
                    name: format!("fuzz_s{}_i{}_{}_o{}", opts.seed, iter, safe_name, opt.as_u8()),
                    seed: opts.seed,
                    iter,
                    backend: name.clone(),
                    opt_level: opt.as_u8(),
                    kind: kind.as_str().to_string(),
                    source: final_src,
                    expected: final_plain.render(),
                    actual: final_hooked.render(),
                    culprit,
                    note: Some("auto-shrunken by `depyf fuzz`; replayed bitwise by tests/fuzz_regressions.rs".into()),
                    strict: false,
                    expect_error: false,
                    first_divergent_opt,
                });
                // One bundle per iteration: the same root cause usually
                // fails every remaining combo, and N copies of one finding
                // drown the report.
                break 'combos;
            }
        }
    }
    Ok(report)
}

/// One precomputed serve-fuzz case: the program plus its single-thread
/// plain outcome (the reference every concurrent hooked run diffs
/// against).
struct ServeCase {
    iter: u64,
    src: String,
    plain: RunOutcome,
}

/// What one serve-fuzz thread observed for its partition.
struct ServeSlice {
    runs: u64,
    skipped_budget: u64,
    /// `(iter, opt, kind, hooked render)` per divergence.
    found: Vec<(u64, u8, DivergenceKind, String)>,
}

/// Concurrent differential fuzzing (`depyf fuzz --serve --threads N`):
/// the hooked side of every diff runs on one of N OS threads, all
/// dispatching through a *shared* [`crate::serve::ModuleCache`] — so the
/// property under test shifts from "compiler output is correct" to
/// "compiler output is correct when N callers race one compile cache".
/// Programs and plain outcomes are precomputed single-threaded, the
/// iteration space is partitioned deterministically (`index % N`), and
/// divergences are reported unshrunk (a shrink re-run cannot reproduce
/// a race) with a `serve:<inner>` backend tag.
pub fn run_fuzz_serve(opts: &FuzzOptions, threads: usize) -> Result<FuzzReport, String> {
    let backend_names = if opts.backends.is_empty() { default_backends() } else { opts.backends.clone() };
    // Resolve every name up front so a typo fails fast, not mid-sweep.
    for name in &backend_names {
        resolve_backend(name)?;
    }
    let opt_levels: Vec<OptLevel> =
        if opts.opt_levels.is_empty() { vec![OptLevel::O0, OptLevel::O2] } else { opts.opt_levels.clone() };

    let mut report =
        FuzzReport { seed: opts.seed, iters: opts.iters, runs: 0, skipped_budget: 0, failures: Vec::new() };

    let mut cases: Vec<Arc<ServeCase>> = Vec::new();
    for iter in 0..opts.iters {
        let src = gen_source(opts.seed, iter);
        let plain = run_program(&src, None, opts.budget);
        if plain.status == RunStatus::Budget {
            report.skipped_budget += 1;
            continue;
        }
        cases.push(Arc::new(ServeCase { iter, src, plain }));
    }

    for name in &backend_names {
        // One shared compile cache per backend sweep: exactly the serving
        // topology (`CachingBackend` over N dispatch threads).
        let inner = resolve_backend(name)?;
        let cache = Arc::new(crate::serve::ModuleCache::new());
        let shared: Arc<dyn Backend> =
            Arc::new(crate::serve::CachingBackend::new(inner, Arc::clone(&cache)));
        for &opt in &opt_levels {
            let handles: Vec<std::thread::JoinHandle<ServeSlice>> = (0..threads)
                .map(|t| {
                    let shared = Arc::clone(&shared);
                    let mine: Vec<Arc<ServeCase>> = cases
                        .iter()
                        .enumerate()
                        .filter(|(i, _)| i % threads == t)
                        .map(|(_, c)| Arc::clone(c))
                        .collect();
                    let budget = opts.budget;
                    std::thread::Builder::new()
                        .name(format!("depyf-fuzz-serve-{}", t))
                        .spawn(move || {
                            let mut slice =
                                ServeSlice { runs: 0, skipped_budget: 0, found: Vec::new() };
                            for case in mine {
                                slice.runs += 1;
                                let hooked =
                                    run_program(&case.src, Some((Arc::clone(&shared), opt)), budget);
                                if hooked.status == RunStatus::Budget {
                                    slice.skipped_budget += 1;
                                    continue;
                                }
                                if let Some(kind) = compare(&case.plain, &hooked) {
                                    slice.found.push((case.iter, opt.as_u8(), kind, hooked.render()));
                                }
                            }
                            slice
                        })
                        .expect("spawn fuzz serve thread")
                })
                .collect();
            let mut found: Vec<(u64, u8, DivergenceKind, String)> = Vec::new();
            for h in handles {
                let slice = h.join().map_err(|_| "fuzz serve thread panicked".to_string())?;
                report.runs += slice.runs;
                report.skipped_budget += slice.skipped_budget;
                found.extend(slice.found);
            }
            // Thread join order is arbitrary; the report's order must not be.
            found.sort_by_key(|(iter, o, _, _)| (*iter, *o));
            for (iter, o, kind, actual) in found {
                let case = cases
                    .iter()
                    .find(|c| c.iter == iter)
                    .expect("divergent iter is in the case list");
                let inner_single = resolve_backend(name)?;
                let first_divergent_opt = if opts.bisect_opt {
                    bisect_first_divergent_opt(&case.src, &inner_single, opts.budget)
                } else {
                    None
                };
                let safe_name: String =
                    name.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '_' }).collect();
                report.failures.push(FuzzBundle {
                    name: format!("fuzz_s{}_i{}_serve_{}_o{}", opts.seed, iter, safe_name, o),
                    seed: opts.seed,
                    iter,
                    backend: format!("serve:{}", name),
                    opt_level: o,
                    kind: kind.as_str().to_string(),
                    source: case.src.clone(),
                    expected: case.plain.render(),
                    actual,
                    culprit: None,
                    note: Some(format!(
                        "found by `depyf fuzz --serve --threads {}` (shared module cache, unshrunk)",
                        threads
                    )),
                    strict: false,
                    expect_error: false,
                    first_divergent_opt,
                });
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_opts() -> FuzzOptions {
        FuzzOptions {
            seed: 42,
            iters: 8,
            backends: vec!["eager".into()],
            opt_levels: vec![OptLevel::O0, OptLevel::O2],
            budget: DEFAULT_BUDGET,
            shrink: true,
            serve_threads: None,
            bisect_opt: false,
        }
    }

    #[test]
    fn gen_source_is_deterministic_per_coordinates() {
        for iter in 0..6 {
            assert_eq!(gen_source(42, iter), gen_source(42, iter), "iter {}", iter);
        }
        // Different iterations decorrelate (at least one differs).
        assert!((1..6).any(|i| gen_source(42, i) != gen_source(42, 0)));
    }

    #[test]
    fn quick_sweep_on_eager_finds_nothing() {
        let report = run_fuzz(&quick_opts()).unwrap();
        assert!(report.ok(), "{}", report.render());
        assert_eq!(report.skipped_budget, 0, "{}", report.render());
        assert_eq!(report.runs, 8 * 2, "every program × opt combo must run: {}", report.render());
    }

    #[test]
    fn sweep_is_deterministic() {
        let a = run_fuzz(&quick_opts()).unwrap();
        let b = run_fuzz(&quick_opts()).unwrap();
        assert_eq!(a.runs, b.runs);
        assert_eq!(a.skipped_budget, b.skipped_budget);
        let names = |r: &FuzzReport| r.failures.iter().map(|f| f.name.clone()).collect::<Vec<_>>();
        assert_eq!(names(&a), names(&b));
    }

    #[test]
    fn unknown_backend_is_an_error_not_a_panic() {
        let mut opts = quick_opts();
        opts.backends = vec!["warp-drive".into()];
        assert!(run_fuzz(&opts).unwrap_err().contains("warp-drive"));
    }

    #[test]
    fn serve_mode_clean_sweep_matches_single_thread_reference() {
        let opts = FuzzOptions {
            serve_threads: Some(3),
            backends: vec!["codegen".into()],
            ..quick_opts()
        };
        let report = run_fuzz(&opts).unwrap();
        assert!(report.ok(), "{}", report.render());
        // Every non-budget program ran on every opt level, across threads.
        assert_eq!(report.runs, 8 * 2, "{}", report.render());
    }

    #[test]
    fn serve_mode_is_deterministic_in_counts_and_findings() {
        let opts = FuzzOptions {
            serve_threads: Some(4),
            backends: vec!["eager".into()],
            ..quick_opts()
        };
        let a = run_fuzz(&opts).unwrap();
        let b = run_fuzz(&opts).unwrap();
        assert_eq!(a.runs, b.runs);
        assert_eq!(a.skipped_budget, b.skipped_budget);
        let names = |r: &FuzzReport| r.failures.iter().map(|f| f.name.clone()).collect::<Vec<_>>();
        assert_eq!(names(&a), names(&b));
    }

    #[test]
    fn bisect_on_a_clean_source_reports_no_divergent_level() {
        let src = gen_source(42, 0);
        let backend = resolve_backend("eager").unwrap();
        assert_eq!(bisect_first_divergent_opt(&src, &backend, DEFAULT_BUDGET), None);
    }

    #[test]
    fn wrapper_grammar_resolves() {
        assert!(resolve_backend("resilient:codegen").is_ok());
        assert!(resolve_backend("recording:eager").is_ok());
        assert!(resolve_backend("eager").is_ok());
        assert!(resolve_backend("nope").is_err());
    }
}
