//! Program-level differential fuzzing (`depyf fuzz`).
//!
//! The conformance harness sweeps *graphs*; TorchProbe-style experience
//! says dynamic-compiler bugs concentrate higher up — in capture, guards
//! and control flow. This module closes that gap: it generates whole
//! `pylang` programs from composable templates (data-dependent branches,
//! `for`/`while` loops with `break`/`continue`, closures, container
//! mutation, tensor-shape changes across guard boundaries, mixed
//! int/float/bool arithmetic), applies semantics-preserving and
//! semantics-perturbing mutations, and runs each program twice — once on
//! the plain VM, once under dynamo — diffing printed output, error
//! messages and result **bit patterns** across backends and opt levels.
//!
//! Pipeline per iteration (fully determined by `(seed, iter)`; no
//! wall-clock anywhere):
//!
//! 1. [`generate`](generate::generate) a program, [`mutate`](mutate::mutate) it;
//! 2. run it plain ([`oracle::run_program`]) — instruction-budget
//!    exhaustion skips the iteration;
//! 3. for each backend × opt level, run hooked and [`oracle::compare`];
//! 4. on divergence, [`shrink`](shrink::shrink) the program while the same
//!    failure kind reproduces, chain into the `replay` single-op localizer
//!    ([`localize_source`]), and emit a [`FuzzBundle`] — the committed
//!    regression format replayed by `tests/fuzz_regressions.rs`.
//!
//! Panics on either side are caught under `catch_unwind` and are always
//! findings: the user-input-reachable panics this fuzzer tripped first
//! (capture unary-op unwrap, compiler loop-stack unwraps, builtin shape
//! wraparound) are now typed errors or graceful graph breaks, each pinned
//! by a committed bundle.

pub mod bundle;
pub mod generate;
pub mod mutate;
pub mod oracle;
pub mod prog;
pub mod shrink;

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

use crate::api::{lookup_backend, ArtifactKind, Backend, TraceBundle};
use crate::backend::{replay_bundle, RecordingBackend, ReplayOptions, ResilientBackend};
use crate::bytecode::IsaVersion;
use crate::dynamo::{Dynamo, DynamoConfig, Verbosity};
use crate::graph::opt::OptLevel;
use crate::tensor::Rng;
use crate::vm::Vm;

pub use bundle::FuzzBundle;
pub use oracle::{compare, run_program, DivergenceKind, RunOutcome, RunStatus};

/// Default per-run instruction budget. Loops the generator emits are
/// bounded, so a trip means a mutation produced something pathological —
/// the iteration is skipped, not reported.
pub const DEFAULT_BUDGET: u64 = 500_000;

/// Backends every default fuzz run sweeps: all registered graph compilers
/// plus a wrapper composition. `async` is deliberately not in the default
/// set — its worker threads are exercised by `tests/chaos.rs`, and the
/// oracle wants single-threaded determinism; select it explicitly with
/// `--backend async:<inner>` if wanted.
pub fn default_backends() -> Vec<String> {
    vec![
        "eager".to_string(),
        "sharded".to_string(),
        "batched".to_string(),
        "codegen".to_string(),
        "resilient:codegen".to_string(),
    ]
}

/// Resolve a backend name, honouring the CLI wrapper grammar
/// (`recording:<inner>`, `resilient[:<inner>]`).
pub fn resolve_backend(name: &str) -> Result<Arc<dyn Backend>, String> {
    if let Some(inner) = name.strip_prefix("recording:") {
        return RecordingBackend::wrapping(inner).map(|b| Arc::new(b) as Arc<dyn Backend>).map_err(|e| e.to_string());
    }
    if let Some(inner) = name.strip_prefix("async:") {
        return crate::serve::AsyncBackend::wrapping(inner)
            .map(|b| Arc::new(b) as Arc<dyn Backend>)
            .map_err(|e| e.to_string());
    }
    if name == "resilient" || name.starts_with("resilient:") {
        let inner = name.strip_prefix("resilient:").unwrap_or("eager");
        return ResilientBackend::wrapping(inner).map(|b| Arc::new(b) as Arc<dyn Backend>).map_err(|e| e.to_string());
    }
    lookup_backend(name).ok_or_else(|| format!("unknown backend '{}'", name))
}

/// Options for one [`run_fuzz`] sweep.
#[derive(Clone, Debug)]
pub struct FuzzOptions {
    pub seed: u64,
    pub iters: u64,
    /// Backend names to sweep (empty: [`default_backends`]).
    pub backends: Vec<String>,
    /// Opt levels to sweep (empty: `[O0, O2]`).
    pub opt_levels: Vec<OptLevel>,
    /// Per-run instruction budget.
    pub budget: u64,
    /// Delta-debug failures before bundling (disable for speed when
    /// triaging interactively).
    pub shrink: bool,
}

impl Default for FuzzOptions {
    fn default() -> Self {
        FuzzOptions {
            seed: 42,
            iters: 100,
            backends: Vec::new(),
            opt_levels: Vec::new(),
            budget: DEFAULT_BUDGET,
            shrink: true,
        }
    }
}

/// Outcome of a sweep.
#[derive(Clone, Debug, Default)]
pub struct FuzzReport {
    pub seed: u64,
    pub iters: u64,
    /// Differential runs performed (programs × backends × opt levels).
    pub runs: u64,
    /// Iterations skipped because a side tripped the instruction budget.
    pub skipped_budget: u64,
    pub failures: Vec<FuzzBundle>,
}

impl FuzzReport {
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "fuzz: seed {} — {} program(s), {} differential run(s), {} budget skip(s), {} failure(s)",
            self.seed,
            self.iters,
            self.runs,
            self.skipped_budget,
            self.failures.len()
        );
        for f in &self.failures {
            out.push_str(&format!(
                "\n  {}: {} on {} at O{} (iter {})",
                f.name, f.kind, f.backend, f.opt_level, f.iter
            ));
            if let Some(c) = &f.culprit {
                for line in c.lines() {
                    out.push_str(&format!("\n    {}", line));
                }
            }
        }
        out
    }
}

/// Per-iteration RNG: decorrelates consecutive iterations without any
/// global state (same scheme as the guard-cache hashers: golden-ratio odd
/// multiplier).
fn iter_rng(seed: u64, iter: u64) -> Rng {
    Rng::new(seed ^ iter.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(0xD1B54A32D192ED03))
}

/// The program source for `(seed, iter)` — the repro coordinates printed
/// in reports and stored in bundles.
pub fn gen_source(seed: u64, iter: u64) -> String {
    let mut rng = iter_rng(seed, iter);
    let mut prog = generate::generate(&mut rng);
    mutate::mutate(&mut prog, &mut rng);
    prog.render()
}

/// Chain a shrunken output divergence into the existing `replay` single-op
/// localizer: re-run the program with a recording wrapper around the
/// target backend, then replay every captured trace bundle against the
/// eager oracle with per-op localization. Returns the rendered replay
/// report(s) for bundles that still mismatch, if any.
pub fn localize_source(src: &str, backend_name: &str, opt: OptLevel, budget: u64) -> Option<String> {
    let backend = resolve_backend(backend_name).ok()?;
    let src = src.to_string();
    let result = catch_unwind(AssertUnwindSafe(move || {
        let rec: Arc<dyn Backend> = Arc::new(RecordingBackend::new(Arc::clone(&backend)));
        let mut vm = Vm::new();
        vm.seed(oracle::ORACLE_SEED);
        vm.instr_budget.set(budget);
        let dynamo = Dynamo::new(DynamoConfig {
            backend: rec,
            opt_level: opt,
            verbosity: Verbosity::Quiet,
            ..Default::default()
        });
        vm.eval_hook = Some(dynamo.clone());
        let _ = vm.exec_source(&src, IsaVersion::V310);
        let mut notes = Vec::new();
        for cf in dynamo.compiled() {
            for art in cf.module.artifacts() {
                if art.kind != ArtifactKind::Trace {
                    continue;
                }
                let Ok(tb) = TraceBundle::parse(&art.content) else { continue };
                let opts = ReplayOptions { localize: true, opt_level: opt, ..Default::default() };
                match replay_bundle(&tb, backend.as_ref(), Some(&crate::api::EagerBackend), &opts) {
                    Ok(report) if !report.ok() => notes.push(report.render()),
                    _ => {}
                }
            }
        }
        notes
    }));
    match result {
        Ok(notes) if !notes.is_empty() => Some(notes.join("\n")),
        _ => None,
    }
}

/// Run a full differential sweep. Deterministic in `opts`: same options,
/// same report (counts, failure names, sources, bundles).
pub fn run_fuzz(opts: &FuzzOptions) -> Result<FuzzReport, String> {
    let backend_names = if opts.backends.is_empty() { default_backends() } else { opts.backends.clone() };
    let mut backends: Vec<(String, Arc<dyn Backend>)> = Vec::new();
    for name in &backend_names {
        backends.push((name.clone(), resolve_backend(name)?));
    }
    let opt_levels: Vec<OptLevel> =
        if opts.opt_levels.is_empty() { vec![OptLevel::O0, OptLevel::O2] } else { opts.opt_levels.clone() };

    let mut report =
        FuzzReport { seed: opts.seed, iters: opts.iters, runs: 0, skipped_budget: 0, failures: Vec::new() };

    for iter in 0..opts.iters {
        let mut rng = iter_rng(opts.seed, iter);
        let mut prog = generate::generate(&mut rng);
        mutate::mutate(&mut prog, &mut rng);
        let src = prog.render();

        let plain = run_program(&src, None, opts.budget);
        if plain.status == RunStatus::Budget {
            report.skipped_budget += 1;
            continue;
        }

        'combos: for (name, backend) in &backends {
            for &opt in &opt_levels {
                report.runs += 1;
                let hooked = run_program(&src, Some((Arc::clone(backend), opt)), opts.budget);
                if hooked.status == RunStatus::Budget {
                    report.skipped_budget += 1;
                    continue;
                }
                let Some(kind) = compare(&plain, &hooked) else { continue };

                // Shrink while the same failure kind reproduces on the
                // same backend × opt level.
                let final_prog = if opts.shrink {
                    let backend = Arc::clone(backend);
                    let budget = opts.budget;
                    shrink::shrink(
                        &prog,
                        &mut |cand| {
                            let s = cand.render();
                            let p = run_program(&s, None, budget);
                            if p.status == RunStatus::Budget {
                                return false;
                            }
                            let h = run_program(&s, Some((Arc::clone(&backend), opt)), budget);
                            compare(&p, &h) == Some(kind)
                        },
                        200,
                    )
                } else {
                    prog.clone()
                };
                let final_src = final_prog.render();
                let final_plain = run_program(&final_src, None, opts.budget);
                let final_hooked = run_program(&final_src, Some((Arc::clone(backend), opt)), opts.budget);

                let culprit = if kind == DivergenceKind::Output {
                    localize_source(&final_src, name, opt, opts.budget)
                } else {
                    None
                };
                let safe_name: String =
                    name.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '_' }).collect();
                report.failures.push(FuzzBundle {
                    name: format!("fuzz_s{}_i{}_{}_o{}", opts.seed, iter, safe_name, opt.as_u8()),
                    seed: opts.seed,
                    iter,
                    backend: name.clone(),
                    opt_level: opt.as_u8(),
                    kind: kind.as_str().to_string(),
                    source: final_src,
                    expected: final_plain.render(),
                    actual: final_hooked.render(),
                    culprit,
                    note: Some("auto-shrunken by `depyf fuzz`; replayed bitwise by tests/fuzz_regressions.rs".into()),
                    strict: false,
                    expect_error: false,
                });
                // One bundle per iteration: the same root cause usually
                // fails every remaining combo, and N copies of one finding
                // drown the report.
                break 'combos;
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_opts() -> FuzzOptions {
        FuzzOptions {
            seed: 42,
            iters: 8,
            backends: vec!["eager".into()],
            opt_levels: vec![OptLevel::O0, OptLevel::O2],
            budget: DEFAULT_BUDGET,
            shrink: true,
        }
    }

    #[test]
    fn gen_source_is_deterministic_per_coordinates() {
        for iter in 0..6 {
            assert_eq!(gen_source(42, iter), gen_source(42, iter), "iter {}", iter);
        }
        // Different iterations decorrelate (at least one differs).
        assert!((1..6).any(|i| gen_source(42, i) != gen_source(42, 0)));
    }

    #[test]
    fn quick_sweep_on_eager_finds_nothing() {
        let report = run_fuzz(&quick_opts()).unwrap();
        assert!(report.ok(), "{}", report.render());
        assert_eq!(report.skipped_budget, 0, "{}", report.render());
        assert_eq!(report.runs, 8 * 2, "every program × opt combo must run: {}", report.render());
    }

    #[test]
    fn sweep_is_deterministic() {
        let a = run_fuzz(&quick_opts()).unwrap();
        let b = run_fuzz(&quick_opts()).unwrap();
        assert_eq!(a.runs, b.runs);
        assert_eq!(a.skipped_budget, b.skipped_budget);
        let names = |r: &FuzzReport| r.failures.iter().map(|f| f.name.clone()).collect::<Vec<_>>();
        assert_eq!(names(&a), names(&b));
    }

    #[test]
    fn unknown_backend_is_an_error_not_a_panic() {
        let mut opts = quick_opts();
        opts.backends = vec!["warp-drive".into()];
        assert!(run_fuzz(&opts).unwrap_err().contains("warp-drive"));
    }

    #[test]
    fn wrapper_grammar_resolves() {
        assert!(resolve_backend("resilient:codegen").is_ok());
        assert!(resolve_backend("recording:eager").is_ok());
        assert!(resolve_backend("eager").is_ok());
        assert!(resolve_backend("nope").is_err());
    }
}
