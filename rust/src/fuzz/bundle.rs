//! The committed regression-bundle format (`tests/fuzz_regressions/*.json`).
//!
//! A bundle is everything needed to re-check one finding long after the
//! fuzzer run that produced it: the (shrunken) source, the seed/iteration
//! coordinates it came from, the backend × opt-level it diverged on, both
//! observed behaviours, and — when the replay localizer could pin it — the
//! culprit op. The replay sweep in `tests/fuzz_regressions.rs` re-executes
//! every committed bundle bitwise on every backend in CI.

use std::path::Path;

use crate::api::json::{self, Json};
use crate::api::DepyfError;

pub const FUZZ_BUNDLE_SCHEMA: u32 = 1;

/// One committed fuzz finding.
#[derive(Clone, Debug, PartialEq)]
pub struct FuzzBundle {
    /// File-stem-safe bundle name.
    pub name: String,
    /// Fuzzer coordinates (informational once committed).
    pub seed: u64,
    pub iter: u64,
    /// Backend name (registry name or wrapper composition) the divergence
    /// was observed on.
    pub backend: String,
    pub opt_level: u8,
    /// `DivergenceKind::as_str` value.
    pub kind: String,
    /// The (shrunken) program source.
    pub source: String,
    /// Plain-VM behaviour (`RunOutcome::render`).
    pub expected: String,
    /// Hooked behaviour at the time of capture.
    pub actual: String,
    /// Replay-localizer verdict, when one was reached.
    pub culprit: Option<String>,
    /// Free-form context for future readers.
    pub note: Option<String>,
    /// When true, the regression sweep asserts the plain run's rendering
    /// equals `expected` *exactly* (hand-computed outputs). When false,
    /// `expected` is informational and only plain-vs-hooked agreement is
    /// enforced.
    pub strict: bool,
    /// When true, the plain run must end in a typed error (the bundle pins
    /// a previously-panicking or previously-aborting input).
    pub expect_error: bool,
    /// Filled by `depyf fuzz --bisect-opt`: the lowest opt level (0/1/2)
    /// at which the shrunken divergence reproduces single-threaded.
    /// `None` on bundles captured without bisection, or when the
    /// divergence did not reproduce in the bisect re-run (e.g. a
    /// concurrency-only finding from `--serve` mode).
    pub first_divergent_opt: Option<u8>,
}

impl FuzzBundle {
    pub fn to_json(&self) -> String {
        let opt_str = |v: &Option<String>| match v {
            Some(s) => format!("\"{}\"", json::escape(s)),
            None => "null".to_string(),
        };
        let opt_num = |v: &Option<u8>| match v {
            Some(n) => n.to_string(),
            None => "null".to_string(),
        };
        format!(
            "{{\n  \"schema\": {},\n  \"name\": \"{}\",\n  \"seed\": \"{}\",\n  \"iter\": {},\n  \"backend\": \"{}\",\n  \"opt_level\": {},\n  \"kind\": \"{}\",\n  \"source\": \"{}\",\n  \"expected\": \"{}\",\n  \"actual\": \"{}\",\n  \"culprit\": {},\n  \"note\": {},\n  \"strict\": {},\n  \"expect_error\": {},\n  \"first_divergent_opt\": {}\n}}\n",
            FUZZ_BUNDLE_SCHEMA,
            json::escape(&self.name),
            self.seed,
            self.iter,
            json::escape(&self.backend),
            self.opt_level,
            json::escape(&self.kind),
            json::escape(&self.source),
            json::escape(&self.expected),
            json::escape(&self.actual),
            opt_str(&self.culprit),
            opt_str(&self.note),
            self.strict,
            self.expect_error,
            opt_num(&self.first_divergent_opt),
        )
    }

    pub fn parse(text: &str) -> Result<FuzzBundle, DepyfError> {
        let doc = json::parse(text)?;
        let bad = |what: &str| DepyfError::Parse(format!("fuzz bundle: missing or malformed '{}'", what));
        let str_field = |key: &str| -> Result<String, DepyfError> {
            doc.get(key).and_then(Json::as_str).map(str::to_string).ok_or_else(|| bad(key))
        };
        let num_field = |key: &str| -> Result<f64, DepyfError> {
            doc.get(key).and_then(Json::as_f64).ok_or_else(|| bad(key))
        };
        let opt_field = |key: &str| -> Option<String> {
            doc.get(key).and_then(Json::as_str).map(str::to_string)
        };
        let bool_field = |key: &str| -> bool {
            matches!(doc.get(key), Some(Json::Bool(true)))
        };
        let schema = num_field("schema")? as u32;
        if schema != FUZZ_BUNDLE_SCHEMA {
            return Err(DepyfError::Parse(format!(
                "fuzz bundle: schema {} unsupported (expected {})",
                schema, FUZZ_BUNDLE_SCHEMA
            )));
        }
        // Seed is a string so u64 values survive the f64 number path.
        let seed = str_field("seed")?.parse::<u64>().map_err(|_| bad("seed"))?;
        Ok(FuzzBundle {
            name: str_field("name")?,
            seed,
            iter: num_field("iter")? as u64,
            backend: str_field("backend")?,
            opt_level: num_field("opt_level")? as u8,
            kind: str_field("kind")?,
            source: str_field("source")?,
            expected: str_field("expected")?,
            actual: str_field("actual")?,
            culprit: opt_field("culprit"),
            note: opt_field("note"),
            strict: bool_field("strict"),
            expect_error: bool_field("expect_error"),
            // Absent on bundles committed before the field existed.
            first_divergent_opt: doc
                .get("first_divergent_opt")
                .and_then(Json::as_f64)
                .map(|v| v as u8),
        })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<FuzzBundle, DepyfError> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| DepyfError::Parse(format!("read {}: {}", path.as_ref().display(), e)))?;
        FuzzBundle::parse(&text)
    }

    /// Write the bundle as `<dir>/<name>.json`; returns the path.
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<std::path::PathBuf, DepyfError> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)
            .map_err(|e| DepyfError::Parse(format!("mkdir {}: {}", dir.display(), e)))?;
        let path = dir.join(format!("{}.json", self.name));
        std::fs::write(&path, self.to_json())
            .map_err(|e| DepyfError::Parse(format!("write {}: {}", path.display(), e)))?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> FuzzBundle {
        FuzzBundle {
            name: "fuzz_s42_i7_codegen_o2".into(),
            seed: 42,
            iter: 7,
            backend: "codegen".into(),
            opt_level: 2,
            kind: "output-divergence".into(),
            source: "def f(x):\n    return (x * 2)\n__r0 = f(torch.rand([3]))\nprint(__r0.sum().item())\n".into(),
            expected: "status: ok\noutput: \"1.5\\n\"".into(),
            actual: "status: ok\noutput: \"3.0\\n\"".into(),
            culprit: Some("first divergence at node v1 (mul)".into()),
            note: None,
            strict: false,
            expect_error: false,
            first_divergent_opt: Some(2),
        }
    }

    #[test]
    fn json_round_trip_is_lossless() {
        let b = sample();
        let back = FuzzBundle::parse(&b.to_json()).unwrap();
        assert_eq!(back, b);
    }

    #[test]
    fn seed_survives_as_u64() {
        let mut b = sample();
        b.seed = u64::MAX;
        let back = FuzzBundle::parse(&b.to_json()).unwrap();
        assert_eq!(back.seed, u64::MAX);
    }

    #[test]
    fn bundles_without_bisect_field_still_parse() {
        // Backward compatibility: bundles committed before `--bisect-opt`
        // existed have no `first_divergent_opt` key at all.
        let text = sample().to_json().replace(",\n  \"first_divergent_opt\": 2", "");
        let back = FuzzBundle::parse(&text).unwrap();
        assert_eq!(back.first_divergent_opt, None);
        // And an explicit null parses the same way.
        let text = sample().to_json().replace("\"first_divergent_opt\": 2", "\"first_divergent_opt\": null");
        assert_eq!(FuzzBundle::parse(&text).unwrap().first_divergent_opt, None);
    }

    #[test]
    fn unknown_schema_is_rejected() {
        let text = sample().to_json().replace("\"schema\": 1", "\"schema\": 99");
        assert!(FuzzBundle::parse(&text).is_err());
    }

    #[test]
    fn save_and_load_round_trip() {
        let dir = std::env::temp_dir().join(format!("depyf_fuzz_bundle_{}", std::process::id()));
        let b = sample();
        let path = b.save(&dir).unwrap();
        let back = FuzzBundle::load(&path).unwrap();
        assert_eq!(back, b);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
