//! Seeded structural mutations.
//!
//! Two families, applied 0–3 at a time:
//!
//! * **Semantics-preserving** (`insert_noop`, `dup_call` with the same
//!   shape): the program must still agree bitwise — catches optimizer and
//!   guard-cache bugs that only show up on re-dispatch.
//! * **Semantics-perturbing** (`perturb_shape`, `perturb_const`,
//!   `swap_method`, `drop_frag`): both sides change together — catches
//!   capture bugs on shapes/paths the seed program missed. `swap_method`
//!   occasionally swaps in a method *no* backend supports (`clamp`), which
//!   must degrade to identical errors on both sides, pinning the graceful
//!   graph-break path.

use crate::tensor::Rng;

use super::generate::METHODS;
use super::prog::{Expr, Frag, Prog};

/// An unsupported tensor method: the VM raises, capture must gracefully
/// break — both runs end in the *same* error.
const UNSUPPORTED_METHOD: &str = "clamp";

/// Mutate `prog` in place with 0–3 random mutations.
pub fn mutate(prog: &mut Prog, rng: &mut Rng) {
    let n = rng.below(3);
    for _ in 0..n {
        apply_one(prog, rng);
    }
}

fn apply_one(prog: &mut Prog, rng: &mut Rng) {
    match rng.below(6) {
        0 => dup_call(prog, rng),
        1 => perturb_shape(prog, rng),
        2 => perturb_const(prog, rng),
        3 => swap_method(prog, rng),
        4 => insert_noop(prog, rng),
        _ => drop_frag(prog, rng),
    }
}

/// Duplicate a call site — the second dispatch must hit the guard cache
/// and still produce bit-identical results.
fn dup_call(prog: &mut Prog, rng: &mut Rng) {
    if prog.calls.is_empty() {
        return;
    }
    let i = rng.below(prog.calls.len());
    let c = prog.calls[i].clone();
    prog.calls.insert(i, c);
}

/// Change one dimension of one call site — a guard boundary: the shape
/// change must recompile, not silently reuse a stale executable.
fn perturb_shape(prog: &mut Prog, rng: &mut Rng) {
    if prog.calls.is_empty() {
        return;
    }
    let i = rng.below(prog.calls.len());
    let c = &mut prog.calls[i];
    if c.shape.is_empty() {
        return;
    }
    let d = rng.below(c.shape.len());
    // Stay non-zero and small: zero-size tensors and big allocs are out of
    // scope for the differential oracle.
    c.shape[d] = 1 + rng.below(6);
}

/// Tweak one integer/float constant (or a branch threshold / loop bound).
fn perturb_const(prog: &mut Prog, rng: &mut Rng) {
    // Collect candidate positions first so the choice is uniform.
    let mut n_consts = 0usize;
    for f in &mut prog.body {
        f.walk_exprs_mut(&mut |e| {
            if matches!(e, Expr::ScaleInt(..) | Expr::AddFloat(..)) {
                n_consts += 1;
            }
        });
    }
    let n_extra = prog
        .body
        .iter()
        .filter(|f| matches!(f, Frag::Branch { .. } | Frag::ForLoop { .. } | Frag::WhileLoop { .. }))
        .count();
    let total = n_consts + n_extra;
    if total == 0 {
        return;
    }
    let target = rng.below(total);
    if target < n_consts {
        let mut seen = 0usize;
        for f in &mut prog.body {
            f.walk_exprs_mut(&mut |e| {
                match e {
                    Expr::ScaleInt(_, k) => {
                        if seen == target {
                            *k = (*k % 4) + 1;
                        }
                        seen += 1;
                    }
                    Expr::AddFloat(_, c) => {
                        if seen == target {
                            *c = if c == "0.5" { "1.5".to_string() } else { "0.5".to_string() };
                        }
                        seen += 1;
                    }
                    _ => {}
                }
            });
        }
    } else {
        let mut seen = n_consts;
        for f in &mut prog.body {
            match f {
                Frag::Branch { thr, .. } => {
                    if seen == target {
                        *thr += 1;
                    }
                    seen += 1;
                }
                Frag::ForLoop { n, .. } => {
                    if seen == target {
                        *n = (*n % 5).max(1) + 1;
                    }
                    seen += 1;
                }
                Frag::WhileLoop { start, .. } => {
                    if seen == target {
                        *start = (*start % 5).max(1) + 1;
                    }
                    seen += 1;
                }
                _ => {}
            }
        }
    }
}

/// Swap one unary method for a neighbour; rarely, for an unsupported one.
fn swap_method(prog: &mut Prog, rng: &mut Rng) {
    let unsupported = rng.below(8) == 0;
    let rotation = 1 + rng.below(METHODS.len() - 1);
    let mut n_methods = 0usize;
    for f in &mut prog.body {
        f.walk_exprs_mut(&mut |e| {
            if matches!(e, Expr::Method(..)) {
                n_methods += 1;
            }
        });
    }
    if n_methods == 0 {
        return;
    }
    let target = rng.below(n_methods);
    let mut seen = 0usize;
    for f in &mut prog.body {
        f.walk_exprs_mut(&mut |e| {
            if let Expr::Method(name, _) = e {
                if seen == target {
                    if unsupported {
                        *name = UNSUPPORTED_METHOD.to_string();
                    } else {
                        let idx = METHODS.iter().position(|m| m == name).unwrap_or(0);
                        *name = METHODS[(idx + rotation) % METHODS.len()].to_string();
                    }
                }
                seen += 1;
            }
        });
    }
}

/// Wrap one expression in `(e * 1)` — bit-exact identity, but it changes
/// the captured graph and gives the optimizer something to chew on.
fn insert_noop(prog: &mut Prog, rng: &mut Rng) {
    if prog.body.is_empty() {
        return;
    }
    let i = rng.below(prog.body.len());
    let mut done = false;
    prog.body[i].walk_exprs_mut(&mut |e| {
        if !done {
            let inner = e.clone();
            *e = Expr::ScaleInt(Box::new(inner), 1);
            done = true;
        }
    });
}

/// Drop one fragment. Later references to its destination become
/// NameErrors — which both sides must raise *identically*.
fn drop_frag(prog: &mut Prog, rng: &mut Rng) {
    if prog.body.len() <= 1 {
        return;
    }
    let i = rng.below(prog.body.len());
    prog.body.remove(i);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fuzz::generate::generate;

    #[test]
    fn mutation_is_deterministic() {
        for seed in 0..12u64 {
            let mk = || {
                let mut rng = Rng::new(seed);
                let mut p = generate(&mut rng);
                mutate(&mut p, &mut rng);
                p.render()
            };
            assert_eq!(mk(), mk(), "seed {}", seed);
        }
    }

    #[test]
    fn mutated_programs_still_render_to_parsable_source() {
        for seed in 0..40u64 {
            let mut rng = Rng::new(seed);
            let mut p = generate(&mut rng);
            mutate(&mut p, &mut rng);
            let src = p.render();
            crate::pylang::parse(&src).unwrap_or_else(|e| panic!("seed {}: {}\n{}", seed, e, src));
        }
    }

    #[test]
    fn insert_noop_wraps_without_changing_leaf_vars() {
        let mut rng = Rng::new(3);
        let mut p = generate(&mut rng);
        let before: Vec<String> = p.body.iter().map(|f| f.dst().to_string()).collect();
        insert_noop(&mut p, &mut rng);
        let after: Vec<String> = p.body.iter().map(|f| f.dst().to_string()).collect();
        assert_eq!(before, after);
        assert!(p.render().contains("* 1)"));
    }
}
