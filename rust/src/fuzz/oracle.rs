//! The differential oracle: one program, two executions.
//!
//! The plain VM run is ground truth (it is what `pylang` semantics *are*);
//! the dynamo-hooked run must agree **bitwise** — same printed output,
//! same `__r{i}` result bit patterns, and on failure the same error. Any
//! disagreement, and any panic escaping either side, is a finding.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

use crate::api::Backend;
use crate::bytecode::IsaVersion;
use crate::dynamo::{Dynamo, DynamoConfig, Verbosity};
use crate::graph::opt::OptLevel;
use crate::value::Value;
use crate::vm::Vm;

/// Fixed RNG seed for every oracle VM: both sides must draw identical
/// `torch.rand` inputs for a bitwise diff to mean anything.
pub const ORACLE_SEED: u64 = 7;

/// How one execution ended.
#[derive(Clone, Debug, PartialEq)]
pub enum RunStatus {
    Ok,
    /// The VM raised a (typed) error — the message, traceback excluded.
    Error(String),
    /// A panic escaped to `catch_unwind` — always a finding.
    Panic(String),
    /// The instruction budget tripped: the program is too slow/looping;
    /// the iteration is skipped, not reported.
    Budget,
}

/// Everything the oracle compares.
#[derive(Clone, Debug, PartialEq)]
pub struct RunOutcome {
    pub status: RunStatus,
    /// Captured `print` output.
    pub output: String,
    /// Bit-exact encodings of the `__r{i}` result globals, in order.
    pub results: Vec<String>,
}

impl RunOutcome {
    /// One-string rendering for bundles and reports.
    pub fn render(&self) -> String {
        let head = match &self.status {
            RunStatus::Ok => "ok".to_string(),
            RunStatus::Error(m) => format!("error: {}", m),
            RunStatus::Panic(m) => format!("panic: {}", m),
            RunStatus::Budget => "budget".to_string(),
        };
        let mut out = format!("status: {}\noutput: {:?}", head, self.output);
        for (i, r) in self.results.iter().enumerate() {
            out.push_str(&format!("\n__r{}: {}", i, r));
        }
        out
    }
}

/// Bit-exact value encoding: f32/f64 payloads go through `to_bits`, so
/// `-0.0` vs `0.0` and differing NaN payloads all count as divergence.
pub fn encode_value(v: &Value) -> String {
    match v {
        Value::Tensor(t) => {
            let bits: Vec<String> = t.data().iter().map(|f| format!("{:08x}", f.to_bits())).collect();
            format!("tensor{:?}:{}", t.shape(), bits.join(","))
        }
        Value::Float(f) => format!("float:{:016x}", f.to_bits()),
        Value::Int(i) => format!("int:{}", i),
        Value::Bool(b) => format!("bool:{}", b),
        Value::None => "none".to_string(),
        other => format!("{}:{}", other.type_name(), other.to_display()),
    }
}

fn collect_results(vm: &Vm) -> Vec<String> {
    let mut out = Vec::new();
    for i in 0.. {
        match vm.get_global(&format!("__r{}", i)) {
            Some(v) => out.push(encode_value(&v)),
            None => break,
        }
    }
    out
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Execute `src` on a fresh VM. `backend == None` is the plain run;
/// `Some((backend, opt))` hooks dynamo with that backend at that opt
/// level (quiet, eager fallback — the production default).
pub fn run_program(src: &str, backend: Option<(Arc<dyn Backend>, OptLevel)>, budget: u64) -> RunOutcome {
    let src = src.to_string();
    let result = catch_unwind(AssertUnwindSafe(move || {
        let mut vm = Vm::new();
        vm.seed(ORACLE_SEED);
        vm.instr_budget.set(budget);
        if let Some((b, opt)) = backend {
            let dynamo = Dynamo::new(DynamoConfig {
                backend: b,
                opt_level: opt,
                verbosity: Verbosity::Quiet,
                ..Default::default()
            });
            vm.eval_hook = Some(dynamo);
        }
        let status = match vm.exec_source(&src, IsaVersion::V310) {
            Ok(_) => RunStatus::Ok,
            Err(e) if e.message.contains("instruction budget exceeded") => RunStatus::Budget,
            Err(e) => RunStatus::Error(e.message),
        };
        let output = vm.take_output();
        let results = if status == RunStatus::Ok { collect_results(&vm) } else { Vec::new() };
        RunOutcome { status, output, results }
    }));
    match result {
        Ok(outcome) => outcome,
        Err(payload) => {
            RunOutcome { status: RunStatus::Panic(panic_message(payload)), output: String::new(), results: Vec::new() }
        }
    }
}

/// What kind of disagreement the oracle observed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DivergenceKind {
    /// Both ran to completion; printed output or result bits differ.
    Output,
    /// Both errored, with different messages.
    ErrorMismatch,
    /// One side succeeded where the other errored.
    StatusMismatch,
    /// A panic escaped either side.
    Panic,
}

impl DivergenceKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            DivergenceKind::Output => "output-divergence",
            DivergenceKind::ErrorMismatch => "error-mismatch",
            DivergenceKind::StatusMismatch => "status-mismatch",
            DivergenceKind::Panic => "panic",
        }
    }

    pub fn parse(s: &str) -> Option<DivergenceKind> {
        match s {
            "output-divergence" => Some(DivergenceKind::Output),
            "error-mismatch" => Some(DivergenceKind::ErrorMismatch),
            "status-mismatch" => Some(DivergenceKind::StatusMismatch),
            "panic" => Some(DivergenceKind::Panic),
            _ => None,
        }
    }
}

/// Compare a plain run against a hooked run. `None` means agreement (or
/// an instruction-budget skip — too-slow programs are not findings).
pub fn compare(plain: &RunOutcome, hooked: &RunOutcome) -> Option<DivergenceKind> {
    if plain.status == RunStatus::Budget || hooked.status == RunStatus::Budget {
        return None;
    }
    if matches!(plain.status, RunStatus::Panic(_)) || matches!(hooked.status, RunStatus::Panic(_)) {
        return Some(DivergenceKind::Panic);
    }
    match (&plain.status, &hooked.status) {
        (RunStatus::Ok, RunStatus::Ok) => {
            if plain.output != hooked.output || plain.results != hooked.results {
                Some(DivergenceKind::Output)
            } else {
                None
            }
        }
        (RunStatus::Error(a), RunStatus::Error(b)) => {
            if a != b {
                Some(DivergenceKind::ErrorMismatch)
            } else {
                None
            }
        }
        _ => Some(DivergenceKind::StatusMismatch),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::EagerBackend;

    #[test]
    fn plain_and_hooked_agree_on_a_simple_program() {
        let src = "def f(x):\n    return (x * 2)\n__r0 = f(torch.rand([3]))\nprint(__r0.sum().item())\n";
        let plain = run_program(src, None, 1_000_000);
        assert_eq!(plain.status, RunStatus::Ok, "{:?}", plain);
        assert_eq!(plain.results.len(), 1);
        let hooked = run_program(src, Some((Arc::new(EagerBackend), OptLevel::O0)), 1_000_000);
        assert_eq!(compare(&plain, &hooked), None, "plain:\n{}\nhooked:\n{}", plain.render(), hooked.render());
    }

    #[test]
    fn budget_exhaustion_is_a_skip_not_a_finding() {
        let src = "n = 0\nwhile True:\n    n = n + 1\n";
        let plain = run_program(src, None, 10_000);
        assert_eq!(plain.status, RunStatus::Budget);
        assert_eq!(compare(&plain, &plain), None);
    }

    #[test]
    fn panics_are_caught_and_classified() {
        let plain = RunOutcome { status: RunStatus::Ok, output: "1\n".into(), results: vec![] };
        let panicked = RunOutcome { status: RunStatus::Panic("boom".into()), output: String::new(), results: vec![] };
        assert_eq!(compare(&plain, &panicked), Some(DivergenceKind::Panic));
    }

    #[test]
    fn error_equality_is_agreement_inequality_is_not() {
        let a = RunOutcome { status: RunStatus::Error("nope".into()), output: String::new(), results: vec![] };
        let b = RunOutcome { status: RunStatus::Error("nope".into()), output: String::new(), results: vec![] };
        assert_eq!(compare(&a, &b), None);
        let c = RunOutcome { status: RunStatus::Error("other".into()), output: String::new(), results: vec![] };
        assert_eq!(compare(&a, &c), Some(DivergenceKind::ErrorMismatch));
        let ok = RunOutcome { status: RunStatus::Ok, output: String::new(), results: vec![] };
        assert_eq!(compare(&ok, &a), Some(DivergenceKind::StatusMismatch));
    }

    #[test]
    fn encode_value_is_bit_exact() {
        assert_eq!(encode_value(&Value::Float(0.0)), "float:0000000000000000");
        assert_eq!(encode_value(&Value::Float(-0.0)), "float:8000000000000000");
        assert_ne!(
            encode_value(&Value::tensor(crate::tensor::Tensor::new(vec![1], vec![0.0]))),
            encode_value(&Value::tensor(crate::tensor::Tensor::new(vec![1], vec![-0.0]))),
        );
    }
}
