//! Structured program model for the differential fuzzer.
//!
//! The fuzzer never mutates raw source text — it generates and mutates a
//! small structured representation ([`Prog`]) built from composable
//! templates (branches, loops with `break`/`continue`, closures, container
//! mutation, guard-boundary shape changes) and renders it to `pylang`
//! source. Structure is what makes mutation and shrinking well-typed: a
//! dropped fragment or a simplified expression is still a syntactically
//! valid program, so every oracle run exercises semantics, not the parser.

use std::fmt::Write as _;

/// A tensor-valued expression over previously defined variables.
///
/// The vocabulary is deliberately restricted to operations that are
/// elementwise (shape-preserving) and numerically closed over the fuzzer's
/// input range (`torch.rand` in `[0, 1)` combined with small constants):
/// `+`, `-`, `*` and bounded unary methods. That keeps every generated
/// program valid for *any* call-site shape and free of NaN/inf sources
/// (`/`, `pow`, `log`, `exp` are excluded by construction), so a bitwise
/// output diff means a real capture/compile divergence, not float folklore.
#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    /// A variable reference.
    Var(String),
    /// Elementwise tensor arithmetic: `(a + b)`, `(a - b)`, `(a * b)`.
    Bin(char, Box<Expr>, Box<Expr>),
    /// Zero-argument tensor method: `a.relu()`.
    Method(String, Box<Expr>),
    /// Module-level unary builtin: `torch.relu(a)`.
    Torch(String, Box<Expr>),
    /// Integer scaling: `(a * 3)`.
    ScaleInt(Box<Expr>, i64),
    /// Float offset: `(a + 0.5)` — literal text kept verbatim so rendering
    /// is exact and mutation-stable.
    AddFloat(Box<Expr>, String),
    /// Scale by a previously defined scalar variable: `(a * s0)`.
    ScaleVar(Box<Expr>, String),
    /// Call a generated helper or closure: `h0(a)`.
    Call(String, Box<Expr>),
}

impl Expr {
    pub fn render(&self) -> String {
        match self {
            Expr::Var(v) => v.clone(),
            Expr::Bin(op, a, b) => format!("({} {} {})", a.render(), op, b.render()),
            Expr::Method(m, a) => format!("{}.{}()", a.render(), m),
            Expr::Torch(m, a) => format!("torch.{}({})", m, a.render()),
            Expr::ScaleInt(a, k) => format!("({} * {})", a.render(), k),
            Expr::AddFloat(a, c) => format!("({} + {})", a.render(), c),
            Expr::ScaleVar(a, s) => format!("({} * {})", a.render(), s),
            Expr::Call(f, a) => format!("{}({})", f, a.render()),
        }
    }

    /// Visit every node (pre-order), mutably. Drives index-targeted
    /// mutations without unsafe aliasing gymnastics.
    pub fn walk_mut(&mut self, f: &mut dyn FnMut(&mut Expr)) {
        f(self);
        match self {
            Expr::Var(_) => {}
            Expr::Bin(_, a, b) => {
                a.walk_mut(f);
                b.walk_mut(f);
            }
            Expr::Method(_, a)
            | Expr::Torch(_, a)
            | Expr::ScaleInt(a, _)
            | Expr::AddFloat(a, _)
            | Expr::ScaleVar(a, _)
            | Expr::Call(_, a) => a.walk_mut(f),
        }
    }

    /// Number of nodes in the expression tree.
    pub fn size(&self) -> usize {
        let mut n = 0;
        let mut probe = self.clone();
        probe.walk_mut(&mut |_| n += 1);
        n
    }

    /// The first (leftmost) variable referenced — the shrinker's
    /// "simplify expression to one of its leaves" target.
    pub fn first_var(&self) -> Option<String> {
        match self {
            Expr::Var(v) => Some(v.clone()),
            Expr::Bin(_, a, b) => a.first_var().or_else(|| b.first_var()),
            Expr::Method(_, a)
            | Expr::Torch(_, a)
            | Expr::ScaleInt(a, _)
            | Expr::AddFloat(a, _)
            | Expr::ScaleVar(a, _)
            | Expr::Call(_, a) => a.first_var(),
        }
    }
}

/// Early loop exit injected into a loop body.
#[derive(Clone, Debug, PartialEq)]
pub enum ExitKind {
    Break,
    Continue,
}

#[derive(Clone, Debug, PartialEq)]
pub struct LoopExit {
    /// Fires when the loop variable / countdown counter equals this.
    pub when: i64,
    pub kind: ExitKind,
}

/// One body fragment of the generated function `f`.
#[derive(Clone, Debug, PartialEq)]
pub enum Frag {
    /// `dst = <expr>`
    Assign { dst: String, expr: Expr },
    /// `dst = <text>` — scalar int/float arithmetic (mixed-type coverage).
    Scalar { dst: String, text: String },
    /// Data-dependent branch. `via_item` breaks the graph through
    /// `.item()`; otherwise the comparison stays a (1-element) tensor and
    /// the truthiness test itself is the break point.
    Branch { dst: String, recv: String, via_item: bool, thr: i64, then_expr: Expr, else_expr: Expr },
    /// `acc = init; for var in range(n): [continue-guard] acc = acc + step [break-guard]`
    ForLoop { var: String, n: i64, acc: String, init: Expr, step: Expr, exit: Option<LoopExit> },
    /// Countdown while loop over `counter`, same accumulator scheme.
    WhileLoop { counter: String, start: i64, acc: String, init: Expr, step: Expr, exit: Option<LoopExit> },
    /// Container mutation: build a list, append, reduce with `sum(xs)`.
    ListSum { list: String, dst: String, items: Vec<Expr> },
}

impl Frag {
    /// The tensor variable this fragment defines.
    pub fn dst(&self) -> &str {
        match self {
            Frag::Assign { dst, .. }
            | Frag::Scalar { dst, .. }
            | Frag::Branch { dst, .. }
            | Frag::ListSum { dst, .. } => dst,
            Frag::ForLoop { acc, .. } | Frag::WhileLoop { acc, .. } => acc,
        }
    }

    /// Visit every expression in the fragment, mutably.
    pub fn walk_exprs_mut(&mut self, f: &mut dyn FnMut(&mut Expr)) {
        match self {
            Frag::Assign { expr, .. } => expr.walk_mut(f),
            Frag::Scalar { .. } => {}
            Frag::Branch { then_expr, else_expr, .. } => {
                then_expr.walk_mut(f);
                else_expr.walk_mut(f);
            }
            Frag::ForLoop { init, step, .. } | Frag::WhileLoop { init, step, .. } => {
                init.walk_mut(f);
                step.walk_mut(f);
            }
            Frag::ListSum { items, .. } => {
                for e in items {
                    e.walk_mut(f);
                }
            }
        }
    }

    fn render(&self, out: &mut String) {
        match self {
            Frag::Assign { dst, expr } => {
                let _ = writeln!(out, "    {} = {}", dst, expr.render());
            }
            Frag::Scalar { dst, text } => {
                let _ = writeln!(out, "    {} = {}", dst, text);
            }
            Frag::Branch { dst, recv, via_item, thr, then_expr, else_expr } => {
                if *via_item {
                    let _ = writeln!(out, "    if {}.sum().item() > {}:", recv, thr);
                } else {
                    let _ = writeln!(out, "    if {}.sum() >= {}:", recv, thr);
                }
                let _ = writeln!(out, "        {} = {}", dst, then_expr.render());
                let _ = writeln!(out, "    else:");
                let _ = writeln!(out, "        {} = {}", dst, else_expr.render());
            }
            Frag::ForLoop { var, n, acc, init, step, exit } => {
                let _ = writeln!(out, "    {} = {}", acc, init.render());
                let _ = writeln!(out, "    for {} in range({}):", var, n);
                if let Some(LoopExit { when, kind: ExitKind::Continue }) = exit {
                    let _ = writeln!(out, "        if {} == {}:", var, when);
                    let _ = writeln!(out, "            continue");
                }
                let _ = writeln!(out, "        {} = ({} + {})", acc, acc, step.render());
                if let Some(LoopExit { when, kind: ExitKind::Break }) = exit {
                    let _ = writeln!(out, "        if {} == {}:", var, when);
                    let _ = writeln!(out, "            break");
                }
            }
            Frag::WhileLoop { counter, start, acc, init, step, exit } => {
                let _ = writeln!(out, "    {} = {}", counter, start);
                let _ = writeln!(out, "    {} = {}", acc, init.render());
                let _ = writeln!(out, "    while {} > 0:", counter);
                let _ = writeln!(out, "        {} = ({} + {})", acc, acc, step.render());
                let _ = writeln!(out, "        {} = ({} - 1)", counter, counter);
                // The exit sits after the decrement: a `continue` here must
                // not skip it (that would never terminate).
                if let Some(LoopExit { when, kind }) = exit {
                    let _ = writeln!(out, "        if {} == {}:", counter, when);
                    let kw = match kind {
                        ExitKind::Break => "break",
                        ExitKind::Continue => "continue",
                    };
                    let _ = writeln!(out, "            {}", kw);
                }
            }
            Frag::ListSum { list, dst, items } => {
                let first = items.first().map(|e| e.render()).unwrap_or_else(|| "x".into());
                let _ = writeln!(out, "    {} = [{}]", list, first);
                for e in items.iter().skip(1) {
                    let _ = writeln!(out, "    {}.append({})", list, e.render());
                }
                let _ = writeln!(out, "    {} = sum({})", dst, list);
            }
        }
    }
}

/// A module-level helper function available to body fragments.
#[derive(Clone, Debug, PartialEq)]
pub enum HelperKind {
    /// `def h(t): return (t * k)` — plain user function (graph break).
    Plain { k: i64 },
    /// A closure over a captured scalar — capture aborts on free
    /// variables, so this exercises the skip/fallback path.
    Closure { k: i64 },
}

#[derive(Clone, Debug, PartialEq)]
pub struct Helper {
    pub name: String,
    pub kind: HelperKind,
}

impl Helper {
    fn render(&self, out: &mut String) {
        match &self.kind {
            HelperKind::Plain { k } => {
                let _ = writeln!(out, "def {}(t):", self.name);
                let _ = writeln!(out, "    return (t * {})", k);
            }
            HelperKind::Closure { k } => {
                let _ = writeln!(out, "def __mk_{}():", self.name);
                let _ = writeln!(out, "    n = {}", k);
                let _ = writeln!(out, "    def {}(t):", self.name);
                let _ = writeln!(out, "        return (t + n)");
                let _ = writeln!(out, "    return {}", self.name);
                let _ = writeln!(out, "{} = __mk_{}()", self.name, self.name);
            }
        }
    }
}

/// One top-level invocation of `f`. Distinct shapes across call sites are
/// the guard-boundary coverage: each new shape recompiles, repeats hit the
/// guard cache.
#[derive(Clone, Debug, PartialEq)]
pub struct CallSite {
    pub shape: Vec<usize>,
}

/// A whole generated program: helpers, a single function `f(x)` assembled
/// from fragments, and top-level call sites whose results are printed
/// *and* stored in `__r{i}` globals for bitwise comparison.
#[derive(Clone, Debug, PartialEq)]
pub struct Prog {
    pub helpers: Vec<Helper>,
    pub body: Vec<Frag>,
    /// The variable `f` returns.
    pub ret: String,
    pub calls: Vec<CallSite>,
}

impl Prog {
    pub fn render(&self) -> String {
        let mut out = String::new();
        for h in &self.helpers {
            h.render(&mut out);
        }
        out.push_str("def f(x):\n");
        for frag in &self.body {
            frag.render(&mut out);
        }
        let _ = writeln!(out, "    return {}", self.ret);
        for (i, c) in self.calls.iter().enumerate() {
            let dims: Vec<String> = c.shape.iter().map(|d| d.to_string()).collect();
            let _ = writeln!(out, "__r{} = f(torch.rand([{}]))", i, dims.join(", "));
            let _ = writeln!(out, "print(__r{}.sum().item())", i);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_is_deterministic_and_parenthesized() {
        let e = Expr::Bin(
            '+',
            Box::new(Expr::Method("relu".into(), Box::new(Expr::Var("x".into())))),
            Box::new(Expr::ScaleInt(Box::new(Expr::Var("t0".into())), 3)),
        );
        assert_eq!(e.render(), "(x.relu() + (t0 * 3))");
        assert_eq!(e.size(), 5);
        assert_eq!(e.first_var().as_deref(), Some("x"));
    }

    #[test]
    fn program_renders_to_compilable_source() {
        let prog = Prog {
            helpers: vec![
                Helper { name: "h0".into(), kind: HelperKind::Plain { k: 3 } },
                Helper { name: "g0".into(), kind: HelperKind::Closure { k: 2 } },
            ],
            body: vec![
                Frag::Assign { dst: "t0".into(), expr: Expr::Call("h0".into(), Box::new(Expr::Var("x".into()))) },
                Frag::Branch {
                    dst: "t1".into(),
                    recv: "t0".into(),
                    via_item: true,
                    thr: 2,
                    then_expr: Expr::Var("t0".into()),
                    else_expr: Expr::Method("neg".into(), Box::new(Expr::Var("t0".into()))),
                },
                Frag::ForLoop {
                    var: "i0".into(),
                    n: 3,
                    acc: "t2".into(),
                    init: Expr::Var("t1".into()),
                    step: Expr::Var("x".into()),
                    exit: Some(LoopExit { when: 1, kind: ExitKind::Continue }),
                },
                Frag::ListSum {
                    list: "xs0".into(),
                    dst: "t3".into(),
                    items: vec![Expr::Var("t2".into()), Expr::Call("g0".into(), Box::new(Expr::Var("x".into())))],
                },
            ],
            ret: "t3".into(),
            calls: vec![CallSite { shape: vec![2, 3] }, CallSite { shape: vec![4] }],
        };
        let src = prog.render();
        crate::pylang::compile_module(&src, "<fuzz>", crate::bytecode::IsaVersion::V310)
            .unwrap_or_else(|e| panic!("{}\n{}", e, src));
        assert!(src.contains("def f(x):"));
        assert!(src.contains("__r1 = f(torch.rand([4]))"));
    }
}
