//! Program-level delta debugging.
//!
//! Given a failing program and a predicate "does this still fail the same
//! way?", greedily try structural reductions — drop a call site, drop a
//! fragment, flatten a branch or loop, simplify an expression to its first
//! leaf — accepting any reduction that preserves the failure, until a full
//! pass makes no progress or the run budget is spent. The result is the
//! small program committed in a regression bundle; single-*op* localization
//! is then delegated to the existing `replay` machinery (see
//! [`super::localize_source`]).

use super::prog::{Expr, Frag, Prog};

/// All one-step reductions of `prog`, smallest-step first.
pub fn candidates(prog: &Prog) -> Vec<Prog> {
    let mut out = Vec::new();

    // Drop a call site (keep at least one — no calls, no oracle).
    if prog.calls.len() > 1 {
        for i in 0..prog.calls.len() {
            let mut p = prog.clone();
            p.calls.remove(i);
            out.push(p);
        }
    }

    // Drop a body fragment.
    if prog.body.len() > 1 {
        for i in 0..prog.body.len() {
            let mut p = prog.clone();
            p.body.remove(i);
            out.push(p);
        }
    }

    // Flatten control flow: branch -> its then-assignment, loop -> one
    // unrolled step, list-sum -> its first item.
    for i in 0..prog.body.len() {
        let replacement = match &prog.body[i] {
            Frag::Branch { dst, then_expr, .. } => {
                Some(Frag::Assign { dst: dst.clone(), expr: then_expr.clone() })
            }
            Frag::ForLoop { acc, init, step, .. } | Frag::WhileLoop { acc, init, step, .. } => Some(Frag::Assign {
                dst: acc.clone(),
                expr: Expr::Bin('+', Box::new(init.clone()), Box::new(step.clone())),
            }),
            Frag::ListSum { dst, items, .. } => {
                items.first().map(|e| Frag::Assign { dst: dst.clone(), expr: e.clone() })
            }
            _ => None,
        };
        if let Some(frag) = replacement {
            let mut p = prog.clone();
            p.body[i] = frag;
            out.push(p);
        }
    }

    // Simplify an expression to its first variable leaf.
    for i in 0..prog.body.len() {
        if let Frag::Assign { dst, expr } = &prog.body[i] {
            if expr.size() > 1 {
                if let Some(v) = expr.first_var() {
                    let mut p = prog.clone();
                    p.body[i] = Frag::Assign { dst: dst.clone(), expr: Expr::Var(v) };
                    out.push(p);
                }
            }
        }
    }

    // Drop a helper (only useful once no fragment calls it; the failure
    // predicate rejects the reduction otherwise).
    for i in 0..prog.helpers.len() {
        let mut p = prog.clone();
        p.helpers.remove(i);
        out.push(p);
    }

    out
}

/// Greedy delta-debug: keep applying the first failure-preserving
/// reduction until fixpoint or `max_runs` predicate evaluations.
pub fn shrink(prog: &Prog, still_fails: &mut dyn FnMut(&Prog) -> bool, max_runs: usize) -> Prog {
    let mut cur = prog.clone();
    let mut runs = 0usize;
    loop {
        let mut improved = false;
        for cand in candidates(&cur) {
            if runs >= max_runs {
                return cur;
            }
            runs += 1;
            if still_fails(&cand) {
                cur = cand;
                improved = true;
                break;
            }
        }
        if !improved {
            return cur;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fuzz::generate::generate;
    use crate::tensor::Rng;

    #[test]
    fn candidates_only_shrink() {
        for seed in 0..10u64 {
            let p = generate(&mut Rng::new(seed));
            let base = p.render().len();
            for c in candidates(&p) {
                assert!(!c.calls.is_empty(), "seed {}: candidate lost all call sites", seed);
                assert!(c.render().len() < base + 16, "seed {}: candidate grew", seed);
            }
        }
    }

    #[test]
    fn shrink_reaches_a_fixpoint_under_an_always_failing_predicate() {
        let p = generate(&mut Rng::new(11));
        let shrunk = shrink(&p, &mut |_| true, 500);
        // Everything reducible is reduced: one call, one fragment, helpers gone.
        assert_eq!(shrunk.calls.len(), 1);
        assert_eq!(shrunk.body.len(), 1);
        assert!(shrunk.helpers.is_empty());
        assert!(candidates(&shrunk).iter().all(|c| c == &shrunk || c.render() != shrunk.render()));
    }

    #[test]
    fn shrink_respects_the_predicate() {
        let p = generate(&mut Rng::new(12));
        let keep = p.body.len();
        // Nothing "fails": the program must come back untouched.
        let same = shrink(&p, &mut |_| false, 500);
        assert_eq!(same.body.len(), keep);
        assert_eq!(same.render(), p.render());
    }
}
