//! A line-level debugger over dumped source: breakpoints, stepping, and
//! local inspection. Implements the VM's [`Tracer`] hook so it fires for
//! any code object whose source file is on disk (user sources hijacked
//! into the dump dir, and `__compiled_fn_*.py` graph dumps via the
//! session's graph-tracer adapter).

use std::cell::RefCell;
use std::collections::HashSet;
use std::rc::Rc;

use crate::value::Value;
use crate::vm::Tracer;

/// One recorded stop.
#[derive(Clone, Debug)]
pub struct DebugEvent {
    pub file: String,
    pub line: u32,
    pub func: String,
    /// (name, repr) pairs of locals at the stop.
    pub locals: Vec<(String, String)>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepMode {
    /// Stop at every traced line.
    Step,
    /// Stop only at breakpoints.
    Continue,
}

/// The debugger: install as `vm.tracer`.
pub struct Debugger {
    breakpoints: RefCell<HashSet<(String, u32)>>,
    mode: RefCell<StepMode>,
    events: RefCell<Vec<DebugEvent>>,
    /// Optional live printer (used by the CLI examples).
    pub echo: RefCell<bool>,
}

impl Default for Debugger {
    fn default() -> Self {
        Self::new()
    }
}

impl Debugger {
    pub fn new() -> Debugger {
        Debugger {
            breakpoints: RefCell::new(HashSet::new()),
            mode: RefCell::new(StepMode::Continue),
            events: RefCell::new(Vec::new()),
            echo: RefCell::new(false),
        }
    }

    pub fn shared() -> Rc<Debugger> {
        Rc::new(Debugger::new())
    }

    /// Set a breakpoint by file *suffix* (e.g. `"__compiled_fn_1.py"`) and
    /// 1-based line.
    pub fn break_at(&self, file_suffix: &str, line: u32) {
        self.breakpoints.borrow_mut().insert((file_suffix.to_string(), line));
    }

    pub fn clear_breakpoints(&self) {
        self.breakpoints.borrow_mut().clear();
    }

    pub fn set_mode(&self, m: StepMode) {
        *self.mode.borrow_mut() = m;
    }

    /// All stops recorded so far.
    pub fn events(&self) -> Vec<DebugEvent> {
        self.events.borrow().clone()
    }

    pub fn take_events(&self) -> Vec<DebugEvent> {
        std::mem::take(&mut self.events.borrow_mut())
    }

    fn hit(&self, file: &str, line: u32) -> bool {
        match *self.mode.borrow() {
            StepMode::Step => true,
            StepMode::Continue => self
                .breakpoints
                .borrow()
                .iter()
                .any(|(f, l)| *l == line && file.ends_with(f.as_str())),
        }
    }

    /// Record a stop coming from the graph-tracer adapter.
    pub fn graph_stop(&self, file: &str, line: u32, graph: &str, value_desc: &str) {
        if self.hit(file, line) {
            let ev = DebugEvent {
                file: file.to_string(),
                line,
                func: graph.to_string(),
                locals: vec![("node_value".into(), value_desc.to_string())],
            };
            if *self.echo.borrow() {
                println!("[debugger] {}:{} in {} — {}", ev.file, ev.line, ev.func, value_desc);
            }
            self.events.borrow_mut().push(ev);
        }
    }
}

impl Tracer for Debugger {
    fn on_line(&self, file: &str, line: u32, func: &str, locals: &[(String, Value)]) {
        if self.hit(file, line) {
            let ev = DebugEvent {
                file: file.to_string(),
                line,
                func: func.to_string(),
                locals: locals.iter().map(|(n, v)| (n.clone(), v.repr())).collect(),
            };
            if *self.echo.borrow() {
                println!("[debugger] {}:{} in {}", ev.file, ev.line, ev.func);
            }
            self.events.borrow_mut().push(ev);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bytecode::IsaVersion;
    use crate::pylang::compile_module;
    use crate::vm::Vm;

    #[test]
    fn step_records_every_line() {
        let src = "x = 1\ny = x + 1\nz = y * 2\nprint(z)\n";
        let code = compile_module(src, "/tmp/prog.py", IsaVersion::V310).unwrap();
        let mut vm = Vm::new();
        let dbg = Debugger::shared();
        dbg.set_mode(StepMode::Step);
        vm.tracer = Some(dbg.clone());
        vm.run_module(&code).unwrap();
        let lines: Vec<u32> = dbg.events().iter().map(|e| e.line).collect();
        assert_eq!(lines, vec![1, 2, 3, 4]);
    }

    #[test]
    fn breakpoint_stops_with_locals() {
        let src = "def f(a):\n    b = a * 2\n    c = b + 1\n    return c\nprint(f(10))\n";
        let code = compile_module(src, "/tmp/prog2.py", IsaVersion::V310).unwrap();
        let mut vm = Vm::new();
        let dbg = Debugger::shared();
        dbg.break_at("prog2.py", 3);
        vm.tracer = Some(dbg.clone());
        vm.run_module(&code).unwrap();
        let evs = dbg.events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].line, 3);
        assert_eq!(evs[0].func, "f");
        // local `b` must be visible with value 20 at the stop
        assert!(evs[0].locals.iter().any(|(n, v)| n == "b" && v == "20"), "{:?}", evs[0].locals);
    }

    #[test]
    fn continue_mode_skips_everything_without_breakpoints() {
        let src = "x = 1\ny = 2\n";
        let code = compile_module(src, "/tmp/prog3.py", IsaVersion::V310).unwrap();
        let mut vm = Vm::new();
        let dbg = Debugger::shared();
        vm.tracer = Some(dbg.clone());
        vm.run_module(&code).unwrap();
        assert!(dbg.events().is_empty());
    }
}
