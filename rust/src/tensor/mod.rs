//! A small f32 CPU tensor library.
//!
//! This is the substrate standing in for PyTorch's eager tensor type: the
//! value that user programs manipulate, that dynamo proxies during symbolic
//! evaluation, and that the eager backend computes with. Row-major, f32 only
//! (the dtype the paper's models overwhelmingly use), functional (ops return
//! new tensors; data is shared via `Arc`, so tensors cross threads freely).

mod ops;
mod rng;

pub use ops::*;
pub use rng::Rng;

use std::fmt;
use std::sync::Arc;

/// A dense row-major f32 tensor.
#[derive(Clone)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Arc<Vec<f32>>,
}

impl Tensor {
    /// Build a tensor from a shape and data. Panics if sizes disagree.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {:?} wants {} elems, got {}", shape, n, data.len());
        Tensor { shape, data: Arc::new(data) }
    }

    /// A scalar (rank-0) tensor.
    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: Arc::new(vec![v]) }
    }

    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: Arc::new(vec![0.0; n]) }
    }

    /// All-ones tensor.
    pub fn ones(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: Arc::new(vec![1.0; n]) }
    }

    /// `[0, 1, ..., n-1]` as f32.
    pub fn arange(n: usize) -> Tensor {
        Tensor { shape: vec![n], data: Arc::new((0..n).map(|i| i as f32).collect()) }
    }

    /// Standard-normal tensor from a caller-owned PRNG (deterministic).
    pub fn randn(shape: &[usize], rng: &mut Rng) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: Arc::new((0..n).map(|_| rng.normal()).collect()) }
    }

    /// Uniform [0,1) tensor from a caller-owned PRNG.
    pub fn rand(shape: &[usize], rng: &mut Rng) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: Arc::new((0..n).map(|_| rng.uniform()).collect()) }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Reclaim the underlying buffer when this is the only owner (`None`
    /// when the data is shared). Lets executors recycle dead-value
    /// allocations instead of dropping them — see the codegen backend's
    /// free-list.
    pub(crate) fn into_data(self) -> Option<Vec<f32>> {
        Arc::try_unwrap(self.data).ok()
    }

    /// The single element of a rank-0/1-element tensor (`.item()`).
    pub fn item(&self) -> f32 {
        assert_eq!(self.numel(), 1, "item() on tensor with {} elements", self.numel());
        self.data[0]
    }

    /// Reinterpret with a new shape (same element count). `-1` handling is
    /// done by the caller (`ops::reshape_infer`).
    pub fn reshape(&self, shape: Vec<usize>) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.numel(), "reshape {:?} -> {:?}", self.shape, shape);
        Tensor { shape, data: Arc::clone(&self.data) }
    }

    /// Strides (in elements) of the row-major layout.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    /// Strides of this tensor aligned onto a broadcast output shape of rank
    /// `out_rank` (>= own rank): missing leading axes and own axes of
    /// extent 1 get stride 0, so walking the output with these strides
    /// revisits the broadcast source elements. Precomputed **once per op**
    /// by the elementwise kernels — the per-element div/mod chain of the
    /// old indexing math is gone.
    pub fn broadcast_strides(&self, out_rank: usize) -> Vec<usize> {
        broadcast_strides_for(&self.shape, out_rank)
    }

    /// Max |a-b| against another tensor of identical shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// Elementwise approximate equality.
    pub fn allclose(&self, other: &Tensor, tol: f32) -> bool {
        self.shape == other.shape && self.max_abs_diff(other) <= tol
    }
}

/// Shape-only form of [`Tensor::broadcast_strides`] — the eager backend's
/// fused regions precompute strides at plan time, before any tensor
/// exists.
pub fn broadcast_strides_for(shape: &[usize], out_rank: usize) -> Vec<usize> {
    debug_assert!(out_rank >= shape.len());
    // Row-major strides of `shape` itself.
    let mut own = vec![1usize; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        own[i] = own[i + 1] * shape[i + 1];
    }
    let offset = out_rank - shape.len();
    let mut s = vec![0usize; out_rank];
    for i in 0..shape.len() {
        s[offset + i] = if shape[i] == 1 { 0 } else { own[i] };
    }
    s
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let preview: Vec<f32> = self.data.iter().take(8).cloned().collect();
        write!(f, "Tensor(shape={:?}, data={:?}{})", self.shape, preview, if self.numel() > 8 { ", ..." } else { "" })
    }
}

impl fmt::Display for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.numel() == 1 {
            return write!(f, "tensor({:.4})", self.data[0]);
        }
        let preview: Vec<String> = self.data.iter().take(6).map(|v| format!("{:.4}", v)).collect();
        write!(f, "tensor(shape={:?}, [{}{}])", self.shape, preview.join(", "), if self.numel() > 6 { ", ..." } else { "" })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_item() {
        let t = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.numel(), 4);
        assert_eq!(Tensor::scalar(7.5).item(), 7.5);
    }

    #[test]
    fn zeros_ones_arange() {
        assert_eq!(Tensor::zeros(&[3]).data(), &[0.0, 0.0, 0.0]);
        assert_eq!(Tensor::ones(&[2]).data(), &[1.0, 1.0]);
        assert_eq!(Tensor::arange(3).data(), &[0.0, 1.0, 2.0]);
    }

    #[test]
    fn strides_row_major() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
    }

    #[test]
    fn broadcast_strides_zero_out_broadcast_axes() {
        let t = Tensor::zeros(&[3, 1]);
        assert_eq!(t.broadcast_strides(2), vec![1, 0]);
        assert_eq!(t.broadcast_strides(4), vec![0, 0, 1, 0]);
        assert_eq!(Tensor::scalar(1.0).broadcast_strides(3), vec![0, 0, 0]);
    }

    #[test]
    fn randn_deterministic() {
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        let a = Tensor::randn(&[4, 4], &mut r1);
        let b = Tensor::randn(&[4, 4], &mut r2);
        assert!(a.allclose(&b, 0.0));
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }
}
