//! Tensor operations: broadcasting elementwise ops, matmul, reductions,
//! activations, normalization, and the couple of NN-specific ops the model
//! corpus needs (embedding gather, cross-entropy).
//!
//! These double as the **eager backend** semantics: graph execution in
//! `backend::eager` calls straight into this module, and the XLA backend is
//! cross-checked against it.
//!
//! Failures are reported as typed [`TensorError`]s so callers (backends,
//! the graph IR, the VM) can distinguish shape mismatches from axis and
//! data-range errors without string matching; `?` still flows into the
//! `String`-erroring VM layers via `From<TensorError> for String`.

use std::fmt;

use super::Tensor;

/// A typed tensor-library failure. Backends match on the variant (is this
/// a shape problem or bad integer data?) instead of sniffing messages.
#[derive(Clone, Debug, PartialEq)]
pub enum TensorError {
    /// Incompatible shapes: broadcast mismatches, matmul dims, reshape
    /// specs, parameter shapes.
    Shape(String),
    /// A reduce/permute axis out of range for the operand.
    Axis { axis: usize, shape: Vec<usize> },
    /// Integer-valued data out of range (embedding ids, class targets) —
    /// the f32-only library's analogue of a dtype error.
    Index(String),
}

impl TensorError {
    fn shape(msg: impl Into<String>) -> TensorError {
        TensorError::Shape(msg.into())
    }

    /// Stable variant tag ("shape" / "axis" / "index").
    pub fn kind(&self) -> &'static str {
        match self {
            TensorError::Shape(_) => "shape",
            TensorError::Axis { .. } => "axis",
            TensorError::Index(_) => "index",
        }
    }
}

impl fmt::Display for TensorError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TensorError::Shape(m) | TensorError::Index(m) => f.write_str(m),
            TensorError::Axis { axis, shape } => {
                write!(f, "reduce axis {} out of range for {:?}", axis, shape)
            }
        }
    }
}

impl std::error::Error for TensorError {}

impl From<TensorError> for String {
    fn from(e: TensorError) -> String {
        e.to_string()
    }
}

/// Broadcast two shapes (numpy rules). Returns the broadcast shape or a
/// [`TensorError::Shape`] describing the mismatch.
pub fn broadcast_shapes(a: &[usize], b: &[usize]) -> Result<Vec<usize>, TensorError> {
    let rank = a.len().max(b.len());
    let mut out = vec![0usize; rank];
    for i in 0..rank {
        let da = if i < rank - a.len() { 1 } else { a[i - (rank - a.len())] };
        let db = if i < rank - b.len() { 1 } else { b[i - (rank - b.len())] };
        out[i] = if da == db {
            da
        } else if da == 1 {
            db
        } else if db == 1 {
            da
        } else {
            return Err(TensorError::shape(format!("cannot broadcast {:?} with {:?}", a, b)));
        };
    }
    Ok(out)
}

/// Reference implementation of broadcast indexing: map a flat index in the
/// broadcast output back to a flat index in `t` with a per-element div/mod
/// chain over every axis. Kept (test-only) as the oracle the stride-based
/// fast path in [`binary_op`] is equivalence-tested against.
#[cfg(test)]
fn broadcast_src_index(out_shape: &[usize], out_idx: usize, t: &Tensor) -> usize {
    let t_shape = t.shape();
    let t_strides = t.strides();
    let offset = out_shape.len() - t_shape.len();
    let mut rem = out_idx;
    let mut src = 0usize;
    for (i, &dim) in out_shape.iter().enumerate() {
        // out stride for axis i
        let stride: usize = out_shape[i + 1..].iter().product();
        let coord = rem / stride;
        rem %= stride;
        let _ = dim;
        if i >= offset {
            let ti = i - offset;
            let tc = if t_shape[ti] == 1 { 0 } else { coord };
            src += tc * t_strides[ti];
        }
    }
    src
}

/// Elementwise binary op with broadcasting.
///
/// Fast paths: identical shapes (linear zip) and a 1-element operand on
/// either side (linear map with a captured scalar). The general path
/// precomputes one broadcast-aligned stride vector per operand
/// ([`Tensor::broadcast_strides`]) and walks the output with an odometer —
/// source indices advance by per-axis deltas, no division or modulo in the
/// element loop.
pub fn binary_op(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Tensor, TensorError> {
    let out_shape = broadcast_shapes(a.shape(), b.shape())?;
    // Fast path: identical shapes.
    if a.shape() == b.shape() {
        let data: Vec<f32> = a.data().iter().zip(b.data().iter()).map(|(&x, &y)| f(x, y)).collect();
        return Ok(Tensor::new(out_shape, data));
    }
    // Fast path: one side is a single element (scalars, [1], [1,1], ...).
    // All broadcast dims are 1 then, so the other side's flat order *is*
    // the output order.
    if b.numel() == 1 {
        let y = b.data()[0];
        let data: Vec<f32> = a.data().iter().map(|&x| f(x, y)).collect();
        return Ok(Tensor::new(out_shape, data));
    }
    if a.numel() == 1 {
        let x = a.data()[0];
        let data: Vec<f32> = b.data().iter().map(|&y| f(x, y)).collect();
        return Ok(Tensor::new(out_shape, data));
    }
    let rank = out_shape.len();
    let n: usize = out_shape.iter().product();
    let sa = a.broadcast_strides(rank);
    let sb = b.broadcast_strides(rank);
    let (ad, bd) = (a.data(), b.data());
    let mut data = Vec::with_capacity(n);
    let mut coords = vec![0usize; rank];
    let (mut ia, mut ib) = (0usize, 0usize);
    for _ in 0..n {
        data.push(f(ad[ia], bd[ib]));
        // Odometer increment from the innermost axis outward.
        for ax in (0..rank).rev() {
            coords[ax] += 1;
            ia += sa[ax];
            ib += sb[ax];
            if coords[ax] < out_shape[ax] {
                break;
            }
            // Axis rolled over: rewind its contribution and carry.
            coords[ax] = 0;
            ia -= sa[ax] * out_shape[ax];
            ib -= sb[ax] * out_shape[ax];
        }
    }
    Ok(Tensor::new(out_shape, data))
}

pub fn add(a: &Tensor, b: &Tensor) -> Result<Tensor, TensorError> {
    binary_op(a, b, |x, y| x + y)
}
pub fn sub(a: &Tensor, b: &Tensor) -> Result<Tensor, TensorError> {
    binary_op(a, b, |x, y| x - y)
}
pub fn mul(a: &Tensor, b: &Tensor) -> Result<Tensor, TensorError> {
    binary_op(a, b, |x, y| x * y)
}
pub fn div(a: &Tensor, b: &Tensor) -> Result<Tensor, TensorError> {
    binary_op(a, b, |x, y| x / y)
}
pub fn pow(a: &Tensor, b: &Tensor) -> Result<Tensor, TensorError> {
    binary_op(a, b, |x, y| x.powf(y))
}
pub fn maximum(a: &Tensor, b: &Tensor) -> Result<Tensor, TensorError> {
    binary_op(a, b, f32::max)
}
pub fn minimum(a: &Tensor, b: &Tensor) -> Result<Tensor, TensorError> {
    binary_op(a, b, f32::min)
}

/// Elementwise unary op.
pub fn unary_op(a: &Tensor, f: impl Fn(f32) -> f32) -> Tensor {
    Tensor::new(a.shape().to_vec(), a.data().iter().map(|&x| f(x)).collect())
}

pub fn neg(a: &Tensor) -> Tensor {
    unary_op(a, |x| -x)
}
pub fn exp(a: &Tensor) -> Tensor {
    unary_op(a, f32::exp)
}
pub fn log(a: &Tensor) -> Tensor {
    unary_op(a, f32::ln)
}
pub fn sqrt(a: &Tensor) -> Tensor {
    unary_op(a, f32::sqrt)
}
pub fn abs(a: &Tensor) -> Tensor {
    unary_op(a, f32::abs)
}
pub fn relu(a: &Tensor) -> Tensor {
    unary_op(a, |x| x.max(0.0))
}
pub fn tanh(a: &Tensor) -> Tensor {
    unary_op(a, f32::tanh)
}
pub fn sigmoid(a: &Tensor) -> Tensor {
    unary_op(a, sigmoid_scalar)
}

/// Per-element sigmoid — shared by [`sigmoid`] and the fused elementwise
/// kernel in `backend::eager`, so both paths compute identical bits.
#[inline]
pub fn sigmoid_scalar(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// tanh-approximation GELU (the variant JAX uses by default).
pub fn gelu(a: &Tensor) -> Tensor {
    unary_op(a, gelu_scalar)
}

/// Per-element GELU — shared by [`gelu`] and the fused elementwise kernel
/// in `backend::eager`, so both paths compute identical bits.
#[inline]
pub fn gelu_scalar(x: f32) -> f32 {
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
}

/// Matrix multiply. Supports 2D @ 2D, and batched (leading dims must match
/// exactly; the last two dims are contracted).
pub fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor, TensorError> {
    if a.rank() < 2 || b.rank() < 2 {
        return Err(TensorError::shape(format!("matmul needs rank>=2 operands, got {:?} @ {:?}", a.shape(), b.shape())));
    }
    let (am, ak) = (a.shape()[a.rank() - 2], a.shape()[a.rank() - 1]);
    let (bk, bn) = (b.shape()[b.rank() - 2], b.shape()[b.rank() - 1]);
    if ak != bk {
        return Err(TensorError::shape(format!("matmul inner-dim mismatch: {:?} @ {:?}", a.shape(), b.shape())));
    }
    let a_batch: Vec<usize> = a.shape()[..a.rank() - 2].to_vec();
    let b_batch: Vec<usize> = b.shape()[..b.rank() - 2].to_vec();
    // Allow one side to be unbatched.
    let batch: Vec<usize> = if a_batch == b_batch {
        a_batch.clone()
    } else if b_batch.is_empty() {
        a_batch.clone()
    } else if a_batch.is_empty() {
        b_batch.clone()
    } else {
        return Err(TensorError::shape(format!("matmul batch mismatch: {:?} @ {:?}", a.shape(), b.shape())));
    };
    let nbatch: usize = batch.iter().product::<usize>().max(1);
    let mut out = vec![0.0f32; nbatch * am * bn];
    let a_mat = am * ak;
    let b_mat = bk * bn;
    let o_mat = am * bn;
    for bi in 0..nbatch {
        let a_off = if a_batch.is_empty() { 0 } else { bi * a_mat };
        let b_off = if b_batch.is_empty() { 0 } else { bi * b_mat };
        let ad = &a.data()[a_off..a_off + a_mat];
        let bd = &b.data()[b_off..b_off + b_mat];
        let od = &mut out[bi * o_mat..(bi + 1) * o_mat];
        matmul_kernel(ad, bd, od, am, ak, bn);
    }
    let mut shape = batch;
    shape.push(am);
    shape.push(bn);
    Ok(Tensor::new(shape, out))
}

/// When the B panel no longer fits in L1/L2, tile the k dimension so each
/// panel of `MM_KBLOCK` B-rows is reused across every output row before
/// moving on. Per output element the k accumulation order is unchanged
/// (k strictly ascending), so blocked and plain kernels produce bitwise
/// identical results.
const MM_KBLOCK: usize = 64;
/// Panel size (elements of B touched per k-sweep) above which blocking wins.
const MM_BLOCK_MIN_PANEL: usize = 64 * 1024 / 4; // ~64 KiB of f32

/// `od += ad (am×ak) @ bd (ak×bn)`; `od` arrives zeroed.
fn matmul_kernel(ad: &[f32], bd: &[f32], od: &mut [f32], am: usize, ak: usize, bn: usize) {
    if ak * bn < MM_BLOCK_MIN_PANEL {
        // i-k-j loop order: streams through bd rows, vectorizes the j loop.
        for i in 0..am {
            for k in 0..ak {
                let av = ad[i * ak + k];
                if av == 0.0 {
                    continue;
                }
                let brow = &bd[k * bn..(k + 1) * bn];
                let orow = &mut od[i * bn..(i + 1) * bn];
                for j in 0..bn {
                    orow[j] += av * brow[j];
                }
            }
        }
        return;
    }
    for k0 in (0..ak).step_by(MM_KBLOCK) {
        let k1 = (k0 + MM_KBLOCK).min(ak);
        for i in 0..am {
            let arow = &ad[i * ak..(i + 1) * ak];
            let orow = &mut od[i * bn..(i + 1) * bn];
            for k in k0..k1 {
                let av = arow[k];
                if av == 0.0 {
                    continue;
                }
                let brow = &bd[k * bn..(k + 1) * bn];
                for j in 0..bn {
                    orow[j] += av * brow[j];
                }
            }
        }
    }
}

/// Transpose the last two axes.
pub fn transpose(a: &Tensor) -> Result<Tensor, TensorError> {
    if a.rank() < 2 {
        return Err(TensorError::shape(format!("transpose needs rank>=2, got {:?}", a.shape())));
    }
    let r = a.rank();
    let (m, n) = (a.shape()[r - 2], a.shape()[r - 1]);
    let nbatch: usize = a.shape()[..r - 2].iter().product::<usize>().max(1);
    let mut out = vec![0.0f32; a.numel()];
    for b in 0..nbatch {
        let src = &a.data()[b * m * n..(b + 1) * m * n];
        let dst = &mut out[b * m * n..(b + 1) * m * n];
        for i in 0..m {
            for j in 0..n {
                dst[j * m + i] = src[i * n + j];
            }
        }
    }
    let mut shape = a.shape().to_vec();
    shape.swap(r - 2, r - 1);
    Ok(Tensor::new(shape, out))
}

/// General axis permutation.
pub fn permute(a: &Tensor, perm: &[usize]) -> Result<Tensor, TensorError> {
    if perm.len() != a.rank() {
        return Err(TensorError::shape(format!("permute {:?} on rank-{} tensor", perm, a.rank())));
    }
    let in_strides = a.strides();
    let out_shape: Vec<usize> = perm.iter().map(|&p| a.shape()[p]).collect();
    let n = a.numel();
    let mut out = vec![0.0f32; n];
    let mut out_strides = vec![1usize; out_shape.len()];
    for i in (0..out_shape.len().saturating_sub(1)).rev() {
        out_strides[i] = out_strides[i + 1] * out_shape[i + 1];
    }
    for (o, slot) in out.iter_mut().enumerate() {
        let mut rem = o;
        let mut src = 0usize;
        for i in 0..out_shape.len() {
            let c = rem / out_strides[i];
            rem %= out_strides[i];
            src += c * in_strides[perm[i]];
        }
        *slot = a.data()[src];
    }
    Ok(Tensor::new(out_shape, out))
}

/// Reduce over one axis (or all axes if `axis` is None) with a fold.
fn reduce(a: &Tensor, axis: Option<usize>, init: f32, f: impl Fn(f32, f32) -> f32) -> Result<Tensor, TensorError> {
    match axis {
        None => {
            let v = a.data().iter().fold(init, |acc, &x| f(acc, x));
            Ok(Tensor::scalar(v))
        }
        Some(ax) => {
            if ax >= a.rank() {
                return Err(TensorError::Axis { axis: ax, shape: a.shape().to_vec() });
            }
            let outer: usize = a.shape()[..ax].iter().product::<usize>().max(1);
            let len = a.shape()[ax];
            let inner: usize = a.shape()[ax + 1..].iter().product::<usize>().max(1);
            let mut out = vec![init; outer * inner];
            for o in 0..outer {
                for k in 0..len {
                    for i in 0..inner {
                        let v = a.data()[(o * len + k) * inner + i];
                        let slot = &mut out[o * inner + i];
                        *slot = f(*slot, v);
                    }
                }
            }
            let mut shape = a.shape().to_vec();
            shape.remove(ax);
            Ok(Tensor::new(shape, out))
        }
    }
}

pub fn sum(a: &Tensor, axis: Option<usize>) -> Result<Tensor, TensorError> {
    reduce(a, axis, 0.0, |x, y| x + y)
}

pub fn max_reduce(a: &Tensor, axis: Option<usize>) -> Result<Tensor, TensorError> {
    reduce(a, axis, f32::NEG_INFINITY, f32::max)
}

pub fn min_reduce(a: &Tensor, axis: Option<usize>) -> Result<Tensor, TensorError> {
    reduce(a, axis, f32::INFINITY, f32::min)
}

pub fn mean(a: &Tensor, axis: Option<usize>) -> Result<Tensor, TensorError> {
    let denom = match axis {
        None => a.numel() as f32,
        Some(ax) => a.shape()[ax] as f32,
    };
    let s = sum(a, axis)?;
    Ok(unary_op(&s, |x| x / denom))
}

/// Softmax over the last axis, numerically stabilized.
pub fn softmax(a: &Tensor) -> Result<Tensor, TensorError> {
    if a.rank() == 0 {
        return Ok(Tensor::scalar(1.0));
    }
    let n = a.shape()[a.rank() - 1];
    let rows = a.numel() / n;
    let mut out = vec![0.0f32; a.numel()];
    for r in 0..rows {
        let row = &a.data()[r * n..(r + 1) * n];
        let m = row.iter().fold(f32::NEG_INFINITY, |acc, &x| acc.max(x));
        let mut z = 0.0f32;
        for (j, &x) in row.iter().enumerate() {
            let e = (x - m).exp();
            out[r * n + j] = e;
            z += e;
        }
        for j in 0..n {
            out[r * n + j] /= z;
        }
    }
    Ok(Tensor::new(a.shape().to_vec(), out))
}

/// Layer normalization over the last axis with learned scale/shift.
pub fn layernorm(x: &Tensor, gamma: &Tensor, beta: &Tensor, eps: f32) -> Result<Tensor, TensorError> {
    let n = *x.shape().last().ok_or_else(|| TensorError::shape("layernorm on rank-0"))?;
    if gamma.numel() != n || beta.numel() != n {
        return Err(TensorError::shape(format!("layernorm param mismatch: x last dim {}, gamma {}, beta {}", n, gamma.numel(), beta.numel())));
    }
    let rows = x.numel() / n;
    let mut out = vec![0.0f32; x.numel()];
    for r in 0..rows {
        let row = &x.data()[r * n..(r + 1) * n];
        let mean: f32 = row.iter().sum::<f32>() / n as f32;
        let var: f32 = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / n as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for j in 0..n {
            out[r * n + j] = (row[j] - mean) * inv * gamma.data()[j] + beta.data()[j];
        }
    }
    Ok(Tensor::new(x.shape().to_vec(), out))
}

/// Embedding lookup: `ids` is an integer-valued f32 tensor; gathers rows of
/// `table` (shape [vocab, dim]).
pub fn embedding(table: &Tensor, ids: &Tensor) -> Result<Tensor, TensorError> {
    if table.rank() != 2 {
        return Err(TensorError::shape(format!("embedding table must be rank 2, got {:?}", table.shape())));
    }
    let (vocab, dim) = (table.shape()[0], table.shape()[1]);
    let mut out = Vec::with_capacity(ids.numel() * dim);
    for &idf in ids.data() {
        let id = idf as usize;
        if id >= vocab {
            return Err(TensorError::Index(format!("embedding id {} out of vocab {}", id, vocab)));
        }
        out.extend_from_slice(&table.data()[id * dim..(id + 1) * dim]);
    }
    let mut shape = ids.shape().to_vec();
    shape.push(dim);
    Ok(Tensor::new(shape, out))
}

/// Mean cross-entropy between logits [.., n, vocab] and integer targets
/// [.., n] (f32-encoded).
pub fn cross_entropy(logits: &Tensor, targets: &Tensor) -> Result<Tensor, TensorError> {
    let vocab = *logits.shape().last().ok_or_else(|| TensorError::shape("cross_entropy on rank-0 logits"))?;
    let rows = logits.numel() / vocab;
    if targets.numel() != rows {
        return Err(TensorError::shape(format!("cross_entropy: {} rows vs {} targets", rows, targets.numel())));
    }
    let mut total = 0.0f32;
    for r in 0..rows {
        let row = &logits.data()[r * vocab..(r + 1) * vocab];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
        let logz = m + row.iter().map(|&x| (x - m).exp()).sum::<f32>().ln();
        let t = targets.data()[r] as usize;
        if t >= vocab {
            return Err(TensorError::Index(format!("target {} out of vocab {}", t, vocab)));
        }
        total += logz - row[t];
    }
    Ok(Tensor::scalar(total / rows as f32))
}

/// Resolve a reshape spec that may contain a single `-1` wildcard.
pub fn reshape_infer(numel: usize, spec: &[i64]) -> Result<Vec<usize>, TensorError> {
    let mut known: usize = 1;
    let mut wild = None;
    for (i, &d) in spec.iter().enumerate() {
        if d == -1 {
            if wild.is_some() {
                return Err(TensorError::shape("reshape: more than one -1"));
            }
            wild = Some(i);
        } else if d < 0 {
            return Err(TensorError::shape(format!("reshape: bad dim {}", d)));
        } else {
            known *= d as usize;
        }
    }
    let mut out: Vec<usize> = spec.iter().map(|&d| if d < 0 { 0 } else { d as usize }).collect();
    if let Some(i) = wild {
        if known == 0 || numel % known != 0 {
            return Err(TensorError::shape(format!("reshape: cannot infer -1 for numel {} with {:?}", numel, spec)));
        }
        out[i] = numel / known;
    } else if known != numel {
        return Err(TensorError::shape(format!("reshape: {:?} incompatible with numel {}", spec, numel)));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize], data: &[f32]) -> Tensor {
        Tensor::new(shape.to_vec(), data.to_vec())
    }

    #[test]
    fn broadcast_rules() {
        assert_eq!(broadcast_shapes(&[2, 3], &[3]).unwrap(), vec![2, 3]);
        assert_eq!(broadcast_shapes(&[2, 1], &[1, 4]).unwrap(), vec![2, 4]);
        assert_eq!(broadcast_shapes(&[], &[5]).unwrap(), vec![5]);
        assert!(broadcast_shapes(&[2, 3], &[4]).is_err());
    }

    #[test]
    fn add_broadcast() {
        let a = t(&[2, 2], &[1.0, 2.0, 3.0, 4.0]);
        let b = t(&[2], &[10.0, 20.0]);
        let c = add(&a, &b).unwrap();
        assert_eq!(c.data(), &[11.0, 22.0, 13.0, 24.0]);
    }

    #[test]
    fn scalar_broadcast() {
        let a = t(&[3], &[1.0, 2.0, 3.0]);
        let c = mul(&a, &Tensor::scalar(2.0)).unwrap();
        assert_eq!(c.data(), &[2.0, 4.0, 6.0]);
    }

    #[test]
    fn matmul_2d() {
        let a = t(&[2, 2], &[1.0, 2.0, 3.0, 4.0]);
        let b = t(&[2, 2], &[1.0, 1.0, 1.0, 1.0]);
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_batched() {
        let a = t(&[2, 1, 2], &[1.0, 2.0, 3.0, 4.0]);
        let b = t(&[2, 2, 1], &[1.0, 1.0, 2.0, 2.0]);
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.shape(), &[2, 1, 1]);
        assert_eq!(c.data(), &[3.0, 14.0]);
    }

    #[test]
    fn matmul_broadcast_rhs() {
        let a = t(&[2, 2, 2], &[1.0, 0.0, 0.0, 1.0, 2.0, 0.0, 0.0, 2.0]);
        let b = t(&[2, 2], &[1.0, 2.0, 3.0, 4.0]);
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.shape(), &[2, 2, 2]);
        assert_eq!(&c.data()[..4], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(&c.data()[4..], &[2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn matmul_mismatch() {
        assert!(matmul(&t(&[2, 3], &[0.0; 6]), &t(&[2, 3], &[0.0; 6])).is_err());
    }

    #[test]
    fn transpose_2d() {
        let a = t(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = transpose(&a).unwrap();
        assert_eq!(b.shape(), &[3, 2]);
        assert_eq!(b.data(), &[1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
    }

    #[test]
    fn permute_3d() {
        let a = Tensor::arange(24).reshape(vec![2, 3, 4]);
        let b = permute(&a, &[2, 0, 1]).unwrap();
        assert_eq!(b.shape(), &[4, 2, 3]);
        // b[i][j][k] == a[j][k][i]
        assert_eq!(b.data()[0], 0.0);
        assert_eq!(b.data()[1 * 2 * 3], 1.0); // i=1,j=0,k=0 -> a[0][0][1]
    }

    #[test]
    fn reductions() {
        let a = t(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(sum(&a, None).unwrap().item(), 21.0);
        assert_eq!(sum(&a, Some(0)).unwrap().data(), &[5.0, 7.0, 9.0]);
        assert_eq!(sum(&a, Some(1)).unwrap().data(), &[6.0, 15.0]);
        assert_eq!(mean(&a, None).unwrap().item(), 3.5);
        assert_eq!(max_reduce(&a, Some(1)).unwrap().data(), &[3.0, 6.0]);
        assert_eq!(min_reduce(&a, None).unwrap().item(), 1.0);
    }

    #[test]
    fn softmax_rows() {
        let a = t(&[2, 2], &[0.0, 0.0, 1000.0, 1000.0]);
        let s = softmax(&a).unwrap();
        for &v in s.data() {
            assert!((v - 0.5).abs() < 1e-6);
        }
    }

    #[test]
    fn layernorm_basic() {
        let x = t(&[1, 4], &[1.0, 2.0, 3.0, 4.0]);
        let g = Tensor::ones(&[4]);
        let b = Tensor::zeros(&[4]);
        let y = layernorm(&x, &g, &b, 1e-5).unwrap();
        let m: f32 = y.data().iter().sum::<f32>() / 4.0;
        assert!(m.abs() < 1e-5);
    }

    #[test]
    fn embedding_gather() {
        let table = t(&[3, 2], &[0.0, 1.0, 10.0, 11.0, 20.0, 21.0]);
        let ids = t(&[2], &[2.0, 0.0]);
        let e = embedding(&table, &ids).unwrap();
        assert_eq!(e.shape(), &[2, 2]);
        assert_eq!(e.data(), &[20.0, 21.0, 0.0, 1.0]);
        assert!(embedding(&table, &t(&[1], &[5.0])).is_err());
    }

    #[test]
    fn cross_entropy_uniform() {
        let logits = t(&[2, 4], &[0.0; 8]);
        let targets = t(&[2], &[1.0, 3.0]);
        let ce = cross_entropy(&logits, &targets).unwrap();
        assert!((ce.item() - (4.0f32).ln()).abs() < 1e-5);
    }

    /// Reference broadcasting (div/mod per element) vs the stride-based
    /// fast path, across ranks 0-4 with every mix of broadcast-1 axes.
    #[test]
    fn stride_broadcast_matches_reference_ranks_0_to_4() {
        use super::super::Rng;
        let mut rng = Rng::new(0xB40ADCA5);
        let base: Vec<usize> = vec![2, 3, 2, 3];
        let mut cases: Vec<(Vec<usize>, Vec<usize>)> = Vec::new();
        for rank_a in 0..=4usize {
            for rank_b in 0..=4usize {
                let rank = rank_a.max(rank_b);
                // Each side takes the trailing axes of the shared base and
                // independently squashes a mask of them to 1 — compatible
                // by construction, covering every broadcast-axis mix.
                for mask in 0..16u32 {
                    let sa: Vec<usize> = (0..rank_a)
                        .map(|i| {
                            let oi = rank - rank_a + i;
                            if mask & (1 << (oi % 4)) != 0 { 1 } else { base[oi] }
                        })
                        .collect();
                    let sb: Vec<usize> = (0..rank_b)
                        .map(|i| {
                            let oi = rank - rank_b + i;
                            if mask & (1 << ((oi + 1) % 4)) != 0 { 1 } else { base[oi] }
                        })
                        .collect();
                    cases.push((sa, sb));
                }
            }
        }
        assert!(cases.len() > 100, "case generation broke: {} cases", cases.len());
        for (sa, sb) in cases {
            let a = Tensor::rand(&sa, &mut rng);
            let b = Tensor::rand(&sb, &mut rng);
            let got = sub(&a, &b).unwrap();
            // Reference: per-element div/mod indexing.
            let out_shape = broadcast_shapes(&sa, &sb).unwrap();
            let n: usize = out_shape.iter().product();
            let mut want = Vec::with_capacity(n);
            for i in 0..n {
                let x = a.data()[broadcast_src_index(&out_shape, i, &a)];
                let y = b.data()[broadcast_src_index(&out_shape, i, &b)];
                want.push(x - y);
            }
            assert_eq!(got.shape(), &out_shape[..], "{:?} vs {:?}", sa, sb);
            assert_eq!(got.data(), &want[..], "{:?} vs {:?}", sa, sb);
        }
    }

    /// The blocked matmul kernel must agree with the plain i-k-j loop —
    /// both accumulate each output element in ascending-k order, so the
    /// comparison is exact, not approximate.
    #[test]
    fn blocked_matmul_matches_plain_kernel() {
        use super::super::Rng;
        let mut rng = Rng::new(0x3A7);
        // ak*bn = 130*140 > MM_BLOCK_MIN_PANEL forces the blocked path,
        // with ak deliberately not a multiple of MM_KBLOCK.
        let (am, ak, bn) = (9, 130, 140);
        assert!(ak * bn >= MM_BLOCK_MIN_PANEL);
        let a = Tensor::rand(&[am, ak], &mut rng);
        let b = Tensor::rand(&[ak, bn], &mut rng);
        let got = matmul(&a, &b).unwrap();
        let mut want = vec![0.0f32; am * bn];
        for i in 0..am {
            for k in 0..ak {
                let av = a.data()[i * ak + k];
                if av == 0.0 {
                    continue;
                }
                for j in 0..bn {
                    want[i * bn + j] += av * b.data()[k * bn + j];
                }
            }
        }
        assert_eq!(got.data(), &want[..]);
    }

    #[test]
    fn reshape_wildcard() {
        assert_eq!(reshape_infer(12, &[3, -1]).unwrap(), vec![3, 4]);
        assert_eq!(reshape_infer(12, &[12]).unwrap(), vec![12]);
        assert!(reshape_infer(12, &[5, -1]).is_err());
        assert!(reshape_infer(12, &[-1, -1]).is_err());
    }

    #[test]
    fn activations() {
        let a = t(&[3], &[-1.0, 0.0, 1.0]);
        assert_eq!(relu(&a).data(), &[0.0, 0.0, 1.0]);
        assert!((sigmoid(&a).data()[1] - 0.5).abs() < 1e-6);
        assert!((gelu(&a).data()[2] - 0.8412).abs() < 1e-3);
    }
}
