//! Deterministic PRNG (xorshift64*) — the offline environment has no `rand`
//! crate, and determinism across the eager backend, the XLA backend and the
//! Python reference is required anyway.

/// xorshift64* generator with Box–Muller normal sampling.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    cached_normal: Option<f32>,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        // Avoid the all-zero state.
        Rng { state: seed.wrapping_mul(0x9E3779B97F4A7C15).max(1), cached_normal: None }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform f32 in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) / ((1u64 << 24) as f32)
    }

    /// Uniform usize in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.cached_normal.take() {
            return v;
        }
        // Guard against log(0).
        let u1 = self.uniform().max(1e-7);
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f32::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let v = r.uniform();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / n as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.1, "var {}", var);
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }
}
