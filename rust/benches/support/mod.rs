//! Shared bench harness: timing helpers plus machine-readable reporting.
//!
//! Every bench records its numbers through a [`Reporter`], which merges
//! them into `BENCH_hotpath.json` (override the path with
//! `DEPYF_BENCH_OUT`). Entries are keyed by `(bench, name)`: re-running a
//! bench refreshes its own entries and leaves the other benches' rows
//! intact, so running the whole suite accumulates one combined report.
//!
//! `DEPYF_BENCH_QUICK=1` shrinks iteration counts to smoke-test levels —
//! CI uses it to keep the hot-path benches compiling and running without
//! paying for statistically meaningful timings.
//!
//! Schema:
//!
//! ```json
//! {
//!   "schema_version": 1,
//!   "entries": [
//!     {"bench": "guard_dispatch", "name": "guard_hit", "value": 123.0, "unit": "ns/call"}
//!   ]
//! }
//! ```

// Each bench binary uses its own subset of this harness.
#![allow(dead_code)]

use std::time::Instant;

use depyf::api::json::{self, Json};

pub const REPORT_SCHEMA_VERSION: u64 = 1;

/// True when the suite runs in CI smoke mode.
pub fn quick() -> bool {
    std::env::var("DEPYF_BENCH_QUICK").map(|v| v == "1" || v == "true").unwrap_or(false)
}

/// Scale an iteration count down to smoke level under `DEPYF_BENCH_QUICK`.
pub fn iters(full: usize) -> usize {
    if quick() {
        2
    } else {
        full
    }
}

/// Time a closure (with warmup), returning ns per call.
pub fn time_ns(iterations: usize, mut f: impl FnMut()) -> f64 {
    for _ in 0..iterations.min(10) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iterations {
        f();
    }
    t0.elapsed().as_nanos() as f64 / iterations as f64
}

#[derive(Clone, Debug, PartialEq)]
pub struct Entry {
    pub bench: String,
    pub name: String,
    pub value: f64,
    pub unit: String,
}

/// Collects entries for one bench binary and merges them into the shared
/// report file on `finish()`.
pub struct Reporter {
    bench: String,
    entries: Vec<Entry>,
}

impl Reporter {
    pub fn new(bench: &str) -> Reporter {
        Reporter { bench: bench.to_string(), entries: Vec::new() }
    }

    /// Record one measurement (also echoed to stdout for human runs).
    pub fn record(&mut self, name: &str, value: f64, unit: &str) {
        println!("[bench:{}] {:<32} {:>14.1} {}", self.bench, name, value, unit);
        self.entries.push(Entry {
            bench: self.bench.clone(),
            name: name.to_string(),
            value,
            unit: unit.to_string(),
        });
    }

    /// Merge this run's entries into the report file and write it.
    pub fn finish(self) {
        let path = report_path();
        let mut merged: Vec<Entry> = load_entries(&path)
            .into_iter()
            .filter(|e| e.bench != self.bench)
            .collect();
        merged.extend(self.entries);
        let doc = render(&merged);
        if let Err(e) = std::fs::write(&path, doc) {
            eprintln!("[bench:{}] failed to write {}: {}", self.bench, path, e);
        } else {
            println!("[bench:{}] wrote {} entries to {}", self.bench, merged.len(), path);
        }
    }
}

pub fn report_path() -> String {
    std::env::var("DEPYF_BENCH_OUT").unwrap_or_else(|_| "BENCH_hotpath.json".into())
}

fn load_entries(path: &str) -> Vec<Entry> {
    let Ok(text) = std::fs::read_to_string(path) else {
        return Vec::new();
    };
    let Ok(doc) = json::parse(&text) else {
        return Vec::new();
    };
    let Some(Json::Arr(items)) = doc.get("entries") else {
        return Vec::new();
    };
    items
        .iter()
        .filter_map(|item| {
            Some(Entry {
                bench: item.get("bench")?.as_str()?.to_string(),
                name: item.get("name")?.as_str()?.to_string(),
                value: item.get("value")?.as_f64()?,
                unit: item.get("unit")?.as_str()?.to_string(),
            })
        })
        .collect()
}

fn render(entries: &[Entry]) -> String {
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"schema_version\": {},\n", REPORT_SCHEMA_VERSION));
    out.push_str("  \"entries\": [\n");
    for (i, e) in entries.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"bench\": \"{}\", \"name\": \"{}\", \"value\": {}, \"unit\": \"{}\"}}{}\n",
            json::escape(&e.bench),
            json::escape(&e.name),
            e.value,
            json::escape(&e.unit),
            if i + 1 < entries.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}
