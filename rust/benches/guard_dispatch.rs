//! Hot-path benches: guard-hit dispatch latency (VM-level and raw
//! guard-table lookups), the eager executor's planned MLP step, and the
//! compile cache's hit-vs-miss cost on the PJRT runtime.
//!
//! Run: `cargo bench --bench guard_dispatch`. Emits/merges
//! `BENCH_hotpath.json` (see `benches/support/mod.rs` for the schema);
//! `DEPYF_BENCH_QUICK=1` runs smoke-level iteration counts.

mod support;

use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use depyf::api::{Backend, CompileRequest, EagerBackend, XlaBackend};
use depyf::bytecode::{CodeObject, IsaVersion};
use depyf::dynamo::{Dynamo, DynamoConfig, Guard, GuardTable, Origin};
use depyf::graph::{Graph, OpKind};
use depyf::runtime::Runtime;
use depyf::tensor::{Rng, Tensor};
use depyf::value::Value;
use depyf::vm::Vm;

const SRC: &str = "\
torch.manual_seed(0)
W1 = torch.randn([32, 64])
W2 = torch.randn([64, 32])
def forward(x):
    h = (x @ W1).relu()
    return (h @ W2).softmax().sum()
";

fn mlp_graph(n: usize, d: usize) -> Graph {
    let mut g = Graph::new("bench_mlp");
    let x = g.placeholder("x", &[n, d]);
    let w1 = g.placeholder("w1", &[d, d]);
    let w2 = g.placeholder("w2", &[d, d]);
    let h = g.add_op(OpKind::MatMul, vec![x, w1]).unwrap();
    let r = g.add_op(OpKind::Relu, vec![h]).unwrap();
    let o = g.add_op(OpKind::MatMul, vec![r, w2]).unwrap();
    let s = g.add_op(OpKind::Softmax, vec![o]).unwrap();
    let out = g.add_op(OpKind::Sum(None), vec![s]).unwrap();
    g.set_outputs(vec![out]);
    g
}

/// Guard-hit latency through the full VM dispatch (call + hook + table).
fn bench_vm_guard_hit(rep: &mut support::Reporter) {
    let mut vm = Vm::new();
    let dynamo = Dynamo::new(DynamoConfig::default());
    vm.eval_hook = Some(dynamo.clone());
    vm.exec_source(SRC, IsaVersion::V310).unwrap();
    let f = vm.get_global("forward").unwrap();
    let x = Value::tensor(Tensor::ones(&[16, 32]));
    vm.call(&f, &[x.clone()]).unwrap(); // capture once
    let iters = support::iters(2000);
    let hit = support::time_ns(iters, || {
        vm.call(&f, &[x.clone()]).unwrap();
    });
    rep.record("guard_hit", hit, "ns/call");
    assert!(dynamo.metrics.cache_hits.get() >= 1);

    // Shape-polymorphic steady state: several entries live, calls
    // alternate between them (the bucketed-dispatch case).
    let shapes: [[usize; 2]; 3] = [[16, 32], [8, 32], [4, 32]];
    let xs: Vec<Value> = shapes.iter().map(|s| Value::tensor(Tensor::ones(s))).collect();
    for v in &xs {
        vm.call(&f, &[v.clone()]).unwrap();
    }
    let mut i = 0;
    let alt = support::time_ns(iters, || {
        vm.call(&f, &[xs[i % xs.len()].clone()]).unwrap();
        i += 1;
    });
    rep.record("guard_hit_polymorphic", alt, "ns/call");
}

/// Raw dispatcher cost: table lookup without the VM around it.
fn bench_table_lookup(rep: &mut support::Reporter) {
    let code = Rc::new(CodeObject::new("e", IsaVersion::V311, 1, vec![], vec![], vec![], vec![], vec![]));
    let w = Value::tensor(Tensor::ones(&[64, 64]));
    let mut table = GuardTable::new();
    for rank_extra in 0..8usize {
        let shape: Vec<usize> = std::iter::repeat(2).take(2 + (rank_extra % 3)).collect();
        let mut guards = vec![
            Guard::TensorShape { origin: Origin::Arg(0), shape },
            Guard::Identity { origin: Origin::Global("W".into()), value: w.clone() },
        ];
        guards.push(Guard::ConstEq { origin: Origin::Arg(1), value: Value::Int(rank_extra as i64) });
        table.insert(guards, Rc::clone(&code));
    }
    let mut globals = std::collections::HashMap::new();
    globals.insert("W".to_string(), w);
    // Matches the last rank-2 entry (arg1 == 6).
    let args = vec![Value::tensor(Tensor::ones(&[2, 2])), Value::Int(6)];
    assert!(table.lookup(&args, &globals).is_some());
    let iters = support::iters(200_000);
    let ns = support::time_ns(iters, || {
        std::hint::black_box(table.lookup(&args, &globals));
    });
    rep.record("table_lookup_8_entries", ns, "ns/lookup");
}

/// Planned eager executor on the paper's MLP block.
fn bench_eager_mlp(rep: &mut support::Reporter) {
    let (n, d) = (32, 64);
    let g = Arc::new(mlp_graph(n, d));
    let f = EagerBackend.compile(&CompileRequest::new("bench_mlp", Arc::clone(&g))).unwrap();
    let mut rng = Rng::new(7);
    let inputs: Vec<Rc<Tensor>> = vec![
        Rc::new(Tensor::randn(&[n, d], &mut rng)),
        Rc::new(Tensor::randn(&[d, d], &mut rng)),
        Rc::new(Tensor::randn(&[d, d], &mut rng)),
    ];
    let iters = support::iters(500);
    let ns = support::time_ns(iters, || {
        f.call(&inputs).unwrap();
    });
    rep.record("eager_mlp_step", ns, "ns/call");
}

/// Compile-cache: cold PJRT compile (miss) vs content-hash cache hit.
fn bench_compile_cache(rep: &mut support::Reporter) {
    let cache_dir = std::env::temp_dir().join(format!("depyf_bench_cache_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cache_dir);
    let rt = match Runtime::cpu_with_disk_cache(&cache_dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("[bench:guard_dispatch] PJRT unavailable, skipping compile-cache bench: {}", e);
            return;
        }
    };
    let g = Arc::new(mlp_graph(8, 16));
    let req = CompileRequest::new("bench_cc", Arc::clone(&g)).with_runtime(Some(Arc::clone(&rt)));

    let t0 = Instant::now();
    XlaBackend.compile(&req).expect("xla compile");
    let miss = t0.elapsed().as_nanos() as f64;
    rep.record("compile_cache_miss", miss, "ns (one-shot)");
    assert_eq!(rt.compiles.get(), 1);

    let iters = support::iters(200);
    let hit = support::time_ns(iters, || {
        XlaBackend.compile(&req).expect("xla compile");
    });
    rep.record("compile_cache_hit", hit, "ns/compile");
    assert_eq!(rt.compiles.get(), 1, "hits must not recompile");

    // Fresh runtime over the same disk cache: lowering is skipped.
    let rt2 = Runtime::cpu_with_disk_cache(&cache_dir).expect("pjrt");
    let req2 = CompileRequest::new("bench_cc2", Arc::clone(&g)).with_runtime(Some(Arc::clone(&rt2)));
    let t0 = Instant::now();
    XlaBackend.compile(&req2).expect("xla compile");
    rep.record("compile_cache_disk_warm", t0.elapsed().as_nanos() as f64, "ns (one-shot)");
    assert_eq!(rt2.disk_hits.get(), 1, "disk cache must serve the HLO");
    let _ = std::fs::remove_dir_all(&cache_dir);
}

fn main() {
    let mut rep = support::Reporter::new("guard_dispatch");
    bench_vm_guard_hit(&mut rep);
    bench_table_lookup(&mut rep);
    bench_eager_mlp(&mut rep);
    bench_compile_cache(&mut rep);
    rep.finish();
}
