//! Dynamo frontend overheads: one-time capture cost, cache-hit dispatch
//! (guard evaluation) cost per call, and the eager-vs-compiled steady
//! state. The "compiler must not slow down steady state" bar from
//! DESIGN.md §Perf.
//!
//! Run: `cargo bench --bench dynamo_overhead` (merges into
//! `BENCH_hotpath.json`; `DEPYF_BENCH_QUICK=1` for smoke runs)

mod support;

use std::time::Instant;

use depyf::bytecode::IsaVersion;
use depyf::dynamo::{Dynamo, DynamoConfig};
use depyf::tensor::Tensor;
use depyf::value::Value;
use depyf::vm::Vm;

const SRC: &str = "\
torch.manual_seed(0)
W1 = torch.randn([32, 64])
W2 = torch.randn([64, 32])
def forward(x):
    h = (x @ W1).relu()
    return (h @ W2).softmax().sum()
";

fn bench(name: &str, iters: usize, f: impl FnMut()) -> f64 {
    let per = support::time_ns(iters, f);
    println!("{:<36} {:>12.0} ns/call ({} iters)", name, per, iters);
    per
}

fn main() {
    let mut rep = support::Reporter::new("dynamo_overhead");
    let iters = support::iters(2000);
    let x = Value::tensor(Tensor::ones(&[16, 32]));

    // Plain eager execution (no hook).
    let vm = Vm::new();
    vm.exec_source(SRC, IsaVersion::V310).unwrap();
    let f = vm.get_global("forward").unwrap();
    let eager = bench("eager call (no compiler)", iters, || {
        vm.call(&f, &[x.clone()]).unwrap();
    });
    rep.record("eager_call", eager, "ns/call");

    // Compiled path.
    let mut vm2 = Vm::new();
    let dynamo = Dynamo::new(DynamoConfig::default());
    vm2.eval_hook = Some(dynamo.clone());
    vm2.exec_source(SRC, IsaVersion::V310).unwrap();
    let f2 = vm2.get_global("forward").unwrap();

    // One-time capture cost.
    let t0 = Instant::now();
    vm2.call(&f2, &[x.clone()]).unwrap();
    let capture = t0.elapsed().as_nanos() as f64;
    println!("{:<36} {:>12.0} ns (one-time)", "first call (capture+compile)", capture);
    rep.record("first_call_capture", capture, "ns (one-shot)");

    let hit = bench("cache-hit call (guards + dispatch)", iters, || {
        vm2.call(&f2, &[x.clone()]).unwrap();
    });
    rep.record("cache_hit_call", hit, "ns/call");
    println!(
        "\nsteady-state ratio compiled/eager: {:.2}x ({} captures, {} cache hits)",
        hit / eager,
        dynamo.metrics.captures.get(),
        dynamo.metrics.cache_hits.get()
    );

    // Pure guard-check overhead: intercept cost when args only vary.
    let shapes = [[16usize, 32], [8, 32]];
    let xs: Vec<Value> = shapes.iter().map(|s| Value::tensor(Tensor::ones(s))).collect();
    for v in &xs {
        vm2.call(&f2, &[v.clone()]).unwrap(); // ensure both entries cached
    }
    let mut i = 0;
    let alt = bench("alternating-shape call (2 entries)", iters, || {
        vm2.call(&f2, &[xs[i % 2].clone()]).unwrap();
        i += 1;
    });
    rep.record("alternating_shape_call", alt, "ns/call");
    println!("\ncompile-time total: {:?}", dynamo.metrics.compile_time());
    println!("metrics: {}", dynamo.metrics.report());
    rep.finish();
}
