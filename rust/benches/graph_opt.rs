//! Graph-optimizer benches: what `--opt-level 2` buys over `0` on the
//! eager executor.
//!
//! * `elementwise_chain_*` — gelu residual blocks (the fusion showcase):
//!   call time of the optimized+fused ExecPlan vs the verbatim one, with
//!   the acceptance gate `speedup >= 1.3x` asserted in full runs.
//! * `layernorm_block_*` — gelu/layernorm residual blocks: fusion gains
//!   on a realistic mixed graph (layernorm itself never fuses).
//! * `const_heavy_*` — node-count reduction from const folding + DCE and
//!   the resulting call-time win.
//! * `optimize_ns` — the one-off cost of running the pass pipeline.
//!
//! Run: `cargo bench --bench graph_opt`. Merges into `BENCH_hotpath.json`
//! (`DEPYF_BENCH_QUICK=1` for CI smoke runs, which skip the flaky-on-
//! shared-runners speedup assertion).

mod support;

use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use depyf::api::{Backend, CompileRequest, EagerBackend, OptLevel};
use depyf::graph::{optimize, Graph, OpKind};
use depyf::tensor::{Rng, Tensor};

/// `blocks` of `y = gelu(x * c + bias) + x` — a pure elementwise residual
/// chain with a foldable const subexpression per block.
fn elementwise_chain(rows: usize, d: usize, blocks: usize) -> Graph {
    let mut g = Graph::new("opt_elementwise");
    let x = g.placeholder("x", &[rows, d]);
    let mut cur = x;
    for i in 0..blocks {
        // Const chain the optimizer folds to one scalar.
        let c1 = g.const_scalar(0.5 + i as f64 * 0.01);
        let c2 = g.const_scalar(2.0);
        let c3 = g.const_scalar(1.0);
        let cc = g.add_op(OpKind::Mul, vec![c1, c2]).unwrap();
        let cc2 = g.add_op(OpKind::Mul, vec![cc, c3]).unwrap();
        let bias = g.const_tensor(Tensor::new(
            vec![d],
            (0..d).map(|j| (j as f32) * 0.003 - 0.2).collect(),
        ));
        let t = g.add_op(OpKind::Mul, vec![cur, cc2]).unwrap();
        let tb = g.add_op(OpKind::Add, vec![t, bias]).unwrap();
        let a = g.add_op(OpKind::Gelu, vec![tb]).unwrap();
        let n1 = g.add_op(OpKind::Neg, vec![a]).unwrap();
        let n2 = g.add_op(OpKind::Neg, vec![n1]).unwrap(); // double-neg: erased
        cur = g.add_op(OpKind::Add, vec![n2, cur]).unwrap();
    }
    let s = g.add_op(OpKind::Sum(None), vec![cur]).unwrap();
    g.set_outputs(vec![s]);
    g
}

/// gelu/layernorm residual blocks: `x = layernorm(gelu(x*c) + x, g, b)`.
fn layernorm_blocks(rows: usize, d: usize, blocks: usize) -> Graph {
    let mut g = Graph::new("opt_layernorm");
    let x = g.placeholder("x", &[rows, d]);
    let gamma = g.const_tensor(Tensor::ones(&[d]));
    let beta = g.const_tensor(Tensor::zeros(&[d]));
    let mut cur = x;
    for _ in 0..blocks {
        let c = g.const_scalar(0.9);
        let t = g.add_op(OpKind::Mul, vec![cur, c]).unwrap();
        let a = g.add_op(OpKind::Gelu, vec![t]).unwrap();
        let r = g.add_op(OpKind::Add, vec![a, cur]).unwrap();
        cur = g.add_op(OpKind::LayerNorm, vec![r, gamma, beta]).unwrap();
    }
    let s = g.add_op(OpKind::Sum(None), vec![cur]).unwrap();
    g.set_outputs(vec![s]);
    g
}

/// Const-heavy graph: long constant chains feeding a small live core.
fn const_heavy(d: usize) -> Graph {
    let mut g = Graph::new("opt_const");
    let x = g.placeholder("x", &[d]);
    let mut cc = g.const_tensor(Tensor::ones(&[d]));
    for i in 0..24 {
        let k = g.const_scalar(1.0 + (i % 5) as f64 * 0.1);
        cc = g.add_op(OpKind::Mul, vec![cc, k]).unwrap();
        if i % 3 == 0 {
            cc = g.add_op(OpKind::Sqrt, vec![cc]).unwrap();
        }
    }
    let m = g.add_op(OpKind::Mul, vec![x, cc]).unwrap();
    let s = g.add_op(OpKind::Sum(None), vec![m]).unwrap();
    g.set_outputs(vec![s]);
    g
}

fn inputs_for(g: &Graph, seed: u64) -> Vec<Rc<Tensor>> {
    let mut rng = Rng::new(seed);
    g.input_shapes().into_iter().map(|(_, s)| Rc::new(Tensor::randn(&s, &mut rng))).collect()
}

/// Compile `g` on the eager backend at `level` and time steady-state calls.
/// Returns (ns/call, planned-graph op count). Bitwise equivalence against
/// the -O0 module is asserted before any timing.
fn bench_levels(
    rep: &mut support::Reporter,
    tag: &str,
    g: Graph,
    iters: usize,
    seed: u64,
) -> (f64, f64) {
    let g = Arc::new(g);
    let mk = |level: OptLevel| {
        let req = CompileRequest::new(&g.name.clone(), Arc::clone(&g)).with_opt_level(level);
        let module = EagerBackend.compile(&req).expect("eager compile");
        let ops = req.optimized().graph.num_ops();
        (module, ops)
    };
    let (m0, ops0) = mk(OptLevel::O0);
    let (m2, ops2) = mk(OptLevel::O2);
    let inputs = inputs_for(&g, seed);
    let a = m0.call(&inputs).unwrap();
    let b = m2.call(&inputs).unwrap();
    for (x, y) in a.iter().zip(b.iter()) {
        assert!(
            x.data().iter().zip(y.data().iter()).all(|(p, q)| p.to_bits() == q.to_bits()),
            "{}: -O2 diverged bitwise from -O0",
            tag
        );
    }
    let o0_ns = support::time_ns(iters, || {
        m0.call(&inputs).unwrap();
    });
    let o2_ns = support::time_ns(iters, || {
        m2.call(&inputs).unwrap();
    });
    rep.record(&format!("{}_opt0_call", tag), o0_ns, "ns/call");
    rep.record(&format!("{}_opt2_call", tag), o2_ns, "ns/call");
    rep.record(&format!("{}_opt0_ops", tag), ops0 as f64, "ops");
    rep.record(&format!("{}_opt2_ops", tag), ops2 as f64, "ops");
    let speedup = o0_ns / o2_ns;
    rep.record(&format!("{}_speedup", tag), speedup, "x");
    (speedup, (ops0 - ops2) as f64)
}

fn main() {
    let mut rep = support::Reporter::new("graph_opt");
    let quick = support::quick();

    // Elementwise residual chain: the acceptance bench. 128x256 f32 per
    // tensor (~128 KiB) x 6 blocks — fusion removes every intermediate
    // allocation; folding + neg-neg erasure removes ops outright.
    let (speedup, reduced) =
        bench_levels(&mut rep, "elementwise_chain", elementwise_chain(128, 256, 6), support::iters(60), 1);
    assert!(reduced >= 12.0, "const folding should remove >= 2 ops per block, removed {}", reduced);
    if !quick {
        assert!(
            speedup >= 1.3,
            "acceptance: elementwise chain must speed up >= 1.3x at -O2 (got {:.2}x)",
            speedup
        );
    }

    // gelu/layernorm residual blocks: realistic mixed graph.
    bench_levels(&mut rep, "layernorm_block", layernorm_blocks(64, 192, 4), support::iters(60), 2);

    // Const-heavy graph: folding collapses the whole const chain.
    let (_, const_reduced) = bench_levels(&mut rep, "const_heavy", const_heavy(4096), support::iters(200), 3);
    assert!(const_reduced >= 24.0, "const chain must fold away, removed {}", const_reduced);

    // One-off optimizer cost on the largest bench graph.
    let g = Arc::new(elementwise_chain(128, 256, 6));
    let t0 = Instant::now();
    let opt = optimize(&g, OptLevel::O2);
    rep.record("optimize_ns", t0.elapsed().as_nanos() as f64, "ns (one-shot)");
    rep.record("optimize_rewrites", opt.total_rewrites() as f64, "rewrites");

    rep.finish();
}
