//! Backend throughput: eager reference executor vs the XLA/PJRT backend on
//! captured graphs of increasing size, plus the AOT Pallas attention
//! artifact vs the eager composition. Shows where the compiled path wins
//! (the paper's "backend generates binary executables" claim, quantified).
//!
//! Run: `cargo bench --bench backend_throughput` (artifacts optional; the
//! attention section is skipped if `artifacts/` is missing). Merges into
//! `BENCH_hotpath.json`; `DEPYF_BENCH_QUICK=1` for smoke runs.

mod support;

use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use depyf::api::{Backend, CompileRequest, EagerBackend, XlaBackend};
use depyf::graph::{Graph, OpKind};
use depyf::runtime::Runtime;
use depyf::tensor::{Rng, Tensor};

fn mlp_graph(n: usize, d: usize) -> Graph {
    let mut g = Graph::new("bench_mlp");
    let x = g.placeholder("x", &[n, d]);
    let w1 = g.placeholder("w1", &[d, d]);
    let w2 = g.placeholder("w2", &[d, d]);
    let h = g.add_op(OpKind::MatMul, vec![x, w1]).unwrap();
    let r = g.add_op(OpKind::Relu, vec![h]).unwrap();
    let o = g.add_op(OpKind::MatMul, vec![r, w2]).unwrap();
    let s = g.add_op(OpKind::Softmax, vec![o]).unwrap();
    let out = g.add_op(OpKind::Sum(None), vec![s]).unwrap();
    g.set_outputs(vec![out]);
    g
}

fn time_ns(iters: usize, mut f: impl FnMut()) -> f64 {
    for _ in 0..iters.min(10) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_nanos() as f64 / iters as f64
}

fn main() {
    let mut rep = support::Reporter::new("backend_throughput");
    let rt = Runtime::cpu().expect("pjrt cpu");
    let mut rng = Rng::new(7);
    println!("{:<10} {:>6} {:>14} {:>14} {:>10} {:>14}", "graph", "dim", "eager ns", "xla ns", "speedup", "GFLOP/s(xla)");
    for &d in &[16usize, 32, 64, 128, 256] {
        let n = 32;
        let g = Arc::new(mlp_graph(n, d));
        let flops = g.flops();
        let name = format!("bench_d{}", d);
        let eager = EagerBackend.compile(&CompileRequest::new(&name, Arc::clone(&g))).expect("eager");
        let xla_req = CompileRequest::new(&name, Arc::clone(&g)).with_runtime(Some(Arc::clone(&rt)));
        let xla = XlaBackend.compile(&xla_req).expect("xla compile");
        assert_eq!(xla.backend_name(), "xla", "xla backend failed: {}", xla.backend_name());
        let inputs: Vec<Rc<Tensor>> = vec![
            Rc::new(Tensor::randn(&[n, d], &mut rng)),
            Rc::new(Tensor::randn(&[d, d], &mut rng)),
            Rc::new(Tensor::randn(&[d, d], &mut rng)),
        ];
        // correctness cross-check before timing
        let a = eager.call(&inputs).unwrap();
        let b = xla.call(&inputs).unwrap();
        assert!(a[0].allclose(&b[0], 2e-2 * d as f32), "backend divergence at d={}", d);

        let iters = support::iters(if d >= 128 { 50 } else { 200 });
        let te = time_ns(iters, || {
            eager.call(&inputs).unwrap();
        });
        let tx = time_ns(iters, || {
            xla.call(&inputs).unwrap();
        });
        println!(
            "{:<10} {:>6} {:>14.0} {:>14.0} {:>9.2}x {:>14.2}",
            "mlp",
            d,
            te,
            tx,
            te / tx,
            flops as f64 / tx
        );
        rep.record(&format!("mlp_d{}_eager", d), te, "ns/call");
        rep.record(&format!("mlp_d{}_xla", d), tx, "ns/call");
    }

    // AOT Pallas attention artifact (if built).
    if let Ok(rt2) = Runtime::cpu_with_artifacts("artifacts") {
        if let Ok((exe, art)) = rt2.load_artifact("attention") {
            let shape = &art.input_shapes[0];
            let mk = |seed: u64| {
                let mut r = Rng::new(seed);
                Tensor::randn(shape, &mut r)
            };
            let (q, k, v) = (mk(1), mk(2), mk(3));
            let t = time_ns(support::iters(200), || {
                rt2.execute(&exe, &[&q, &k, &v]).unwrap();
            });
            let (b, h, tt, dd) = (shape[0], shape[1], shape[2], shape[3]);
            let flops = 4 * b * h * tt * tt * dd; // 2 matmuls
            println!(
                "\nAOT Pallas attention {:?}: {:.0} ns/call, {:.2} GFLOP/s (interpret-mode CPU)",
                shape,
                t,
                flops as f64 / t
            );
        }
    } else {
        println!("\n(artifacts/ not built; skipping AOT attention — run `make artifacts`)");
    }
    rep.finish();
}
