//! Bench/regeneration harness for **Table 1** (the paper's only table):
//! decompiler correctness across ISA versions and program-generated
//! bytecode, plus wall-clock per suite.
//!
//! Run: `cargo bench --bench table1_correctness` (merges into
//! `BENCH_hotpath.json`)

mod support;

use depyf::bytecode::IsaVersion;
use depyf::corpus::{render_table1, run_model_suite, run_syntax_suite, run_table1};
use depyf::decompiler::baselines::all_tools_rc;
use depyf::decompiler::DecompilerTool;

fn main() {
    let mut rep = support::Reporter::new("table1_correctness");
    println!("=== Table 1: decompiler correctness (regenerated) ===\n");
    let t0 = std::time::Instant::now();
    let table = run_table1();
    println!("{}", render_table1(&table));
    println!("total wall-clock: {:.2?}\n", t0.elapsed());
    rep.record("table1_wall_clock", t0.elapsed().as_nanos() as f64, "ns (one-shot)");

    println!("=== per-suite timing ===");
    for tool in all_tools_rc() {
        let t = std::time::Instant::now();
        let (cell, _) = run_syntax_suite(tool.as_ref(), IsaVersion::V310);
        let syn = t.elapsed();
        let t = std::time::Instant::now();
        let (mcell, _) = run_model_suite(&tool);
        let mdl = t.elapsed();
        println!(
            "{:<12} syntax@3.10 {:>3}/{} in {:>8.1?}   models {:>3}/{} in {:>8.1?}",
            tool.name(),
            cell.pass,
            cell.total,
            syn,
            mcell.pass,
            mcell.total,
            mdl
        );
        rep.record(&format!("{}_syntax_suite", tool.name()), syn.as_nanos() as f64, "ns (one-shot)");
        rep.record(&format!("{}_model_suite", tool.name()), mdl.as_nanos() as f64, "ns (one-shot)");
    }
    rep.finish();
}
