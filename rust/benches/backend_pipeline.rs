//! Backend-pipeline benches: what the composite backends buy (and cost).
//!
//! * `sharded` vs the monolithic backend on a deep MLP chain — per-call
//!   stitch overhead (eager targets) and per-shard compile behaviour
//!   (PJRT targets, when available).
//! * `batched` vs per-guard-entry compiles — four guard entries whose
//!   batch sizes land in one bucket compile once instead of four times.
//!
//! Run: `cargo bench --bench backend_pipeline`. Merges into
//! `BENCH_hotpath.json`; `DEPYF_BENCH_QUICK=1` for smoke runs.

mod support;

use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use depyf::api::{Backend, CompileRequest, EagerBackend, XlaBackend};
use depyf::backend::{BatchedBackend, ShardedBackend};
use depyf::graph::{Graph, OpKind};
use depyf::runtime::Runtime;
use depyf::tensor::{Rng, Tensor};

/// `layers` matmul+relu blocks ending in softmax+sum: a chain with an
/// articulation point between every block.
fn deep_mlp(batch: usize, d: usize, layers: usize) -> Graph {
    let mut g = Graph::new("bench_pipeline");
    let x = g.placeholder("x", &[batch, d]);
    let mut cur = x;
    for i in 0..layers {
        let w = g.placeholder(&format!("w{}", i), &[d, d]);
        let h = g.add_op(OpKind::MatMul, vec![cur, w]).unwrap();
        cur = g.add_op(OpKind::Relu, vec![h]).unwrap();
    }
    let sm = g.add_op(OpKind::Softmax, vec![cur]).unwrap();
    g.set_outputs(vec![sm]);
    g
}

fn inputs_for(g: &Graph, seed: u64) -> Vec<Rc<Tensor>> {
    let mut rng = Rng::new(seed);
    g.input_shapes().into_iter().map(|(_, s)| Rc::new(Tensor::randn(&s, &mut rng))).collect()
}

/// Sharded (eager targets) vs plain eager: the cost of stitching.
fn bench_sharded_eager(rep: &mut support::Reporter) {
    let g = Arc::new(deep_mlp(16, 32, 4));
    let req = CompileRequest::new("bench_pipeline", Arc::clone(&g));
    let mono = EagerBackend.compile(&req).expect("eager");
    let sharded = ShardedBackend::with_max_ops(3).compile(&req).expect("sharded");
    assert!(sharded.stats().partitions >= 3);
    let inputs = inputs_for(&g, 1);
    // Equivalence before timing.
    let a = mono.call(&inputs).unwrap();
    let b = sharded.call(&inputs).unwrap();
    assert_eq!(a[0].data(), b[0].data(), "sharded diverged from monolithic");
    let iters = support::iters(300);
    let mono_ns = support::time_ns(iters, || {
        mono.call(&inputs).unwrap();
    });
    let shard_ns = support::time_ns(iters, || {
        sharded.call(&inputs).unwrap();
    });
    rep.record("monolithic_eager_call", mono_ns, "ns/call");
    rep.record("sharded_eager_call", shard_ns, "ns/call");
}

/// Sharded vs monolithic XLA: per-shard compiles + stitched execution.
fn bench_sharded_xla(rep: &mut support::Reporter) {
    let Ok(rt) = Runtime::cpu() else {
        eprintln!("[bench:backend_pipeline] PJRT unavailable, skipping xla section");
        return;
    };
    let g = Arc::new(deep_mlp(16, 32, 4));
    let req = CompileRequest::new("bench_pipeline", Arc::clone(&g)).with_runtime(Some(Arc::clone(&rt)));

    let t0 = Instant::now();
    let mono = XlaBackend.compile(&req).expect("xla");
    rep.record("monolithic_xla_compile", t0.elapsed().as_nanos() as f64, "ns (one-shot)");
    let mono_compiles = rt.compiles.get();

    let t0 = Instant::now();
    let sharded = ShardedBackend::with_max_ops(3).compile(&req).expect("sharded xla");
    rep.record("sharded_xla_compile", t0.elapsed().as_nanos() as f64, "ns (one-shot)");
    let shard_compiles = rt.compiles.get() - mono_compiles;
    rep.record("sharded_xla_executables", shard_compiles as f64, "compiles");
    assert!(shard_compiles >= 3, "sharding must produce several executables");

    let inputs = inputs_for(&g, 2);
    let a = mono.call(&inputs).unwrap();
    let b = sharded.call(&inputs).unwrap();
    assert!(a[0].allclose(&b[0], 1e-4), "sharded xla diverged");
    let iters = support::iters(200);
    let mono_ns = support::time_ns(iters, || {
        mono.call(&inputs).unwrap();
    });
    let shard_ns = support::time_ns(iters, || {
        sharded.call(&inputs).unwrap();
    });
    rep.record("monolithic_xla_call", mono_ns, "ns/call");
    rep.record("sharded_xla_call", shard_ns, "ns/call");
}

/// Batched vs per-guard-entry compiles: batch sizes 5..=8 share bucket 8.
fn bench_batched(rep: &mut support::Reporter) {
    let batches = [5usize, 6, 7, 8];
    // Eager targets: one shared ExecPlan instead of four.
    let backend = BatchedBackend::new();
    let t0 = Instant::now();
    let mut bucket_hits = 0u64;
    for &b in &batches {
        let g = Arc::new(deep_mlp(b, 32, 2));
        let req = CompileRequest::new("bench_batched", Arc::clone(&g));
        let module = backend.compile(&req).expect("batched");
        bucket_hits += module.stats().cache_hits;
        // Sanity: padded execution matches the reference executor.
        let inputs = inputs_for(&g, 3 + b as u64);
        let got = module.call(&inputs).unwrap();
        let want = EagerBackend.compile(&req).unwrap().call(&inputs).unwrap();
        assert_eq!(got[0].data(), want[0].data(), "batched diverged at b={}", b);
    }
    rep.record("batched_eager_4entries", t0.elapsed().as_nanos() as f64, "ns (one-shot)");
    rep.record("batched_bucket_reuse", bucket_hits as f64, "cache hits");
    assert_eq!(bucket_hits, batches.len() as u64 - 1, "bucket must be shared");

    // PJRT: four exact executables vs one padded executable. (Distinct
    // widths per section so the runtime's content-hash cache cannot alias
    // the exact batch-8 graph with the padded bucket-8 graph.)
    if let Ok(rt) = Runtime::cpu() {
        let base = rt.compiles.get();
        let t0 = Instant::now();
        for &b in &batches {
            let g = Arc::new(deep_mlp(b, 24, 2));
            let req = CompileRequest::new("bench_batched", Arc::clone(&g))
                .with_runtime(Some(Arc::clone(&rt)));
            XlaBackend.compile(&req).expect("xla");
        }
        let per_entry = rt.compiles.get() - base;
        rep.record("per_entry_xla_compiles", per_entry as f64, "compiles");
        rep.record("per_entry_xla_compile_total", t0.elapsed().as_nanos() as f64, "ns (one-shot)");

        let base = rt.compiles.get();
        let t0 = Instant::now();
        for &b in &batches {
            let g = Arc::new(deep_mlp(b, 48, 2));
            let req = CompileRequest::new("bench_batched", Arc::clone(&g))
                .with_runtime(Some(Arc::clone(&rt)));
            BatchedBackend::new().compile(&req).expect("batched xla");
        }
        let bucketed = rt.compiles.get() - base;
        rep.record("batched_xla_compiles", bucketed as f64, "compiles");
        rep.record("batched_xla_compile_total", t0.elapsed().as_nanos() as f64, "ns (one-shot)");
        assert_eq!(per_entry, 4, "four guard entries, four exact executables");
        assert_eq!(bucketed, 1, "one bucket, one executable");
    }
}

fn main() {
    let mut rep = support::Reporter::new("backend_pipeline");
    bench_sharded_eager(&mut rep);
    bench_sharded_xla(&mut rep);
    bench_batched(&mut rep);
    rep.finish();
}
