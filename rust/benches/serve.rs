//! Serving throughput vs thread count: the concurrent-dispatch subsystem
//! measured end to end (dynamo sessions over the table1 model corpus,
//! shared module cache, per-call latency percentiles).
//!
//! Unlike the hot-path benches this one writes its own report —
//! `BENCH_serve.json` (override with `DEPYF_BENCH_SERVE_OUT`) — because
//! the serve numbers are a scaling curve, not single hot-path samples.
//! Schema matches `BENCH_hotpath.json`:
//! `{"schema_version": 1, "entries": [{"bench", "name", "value", "unit"}]}`.
//!
//! Run: `cargo bench --bench serve` (`DEPYF_BENCH_QUICK=1` for the CI
//! smoke configuration).

mod support;

use depyf::serve::serve_once;

fn out_path() -> String {
    std::env::var("DEPYF_BENCH_SERVE_OUT").unwrap_or_else(|_| "BENCH_serve.json".into())
}

fn main() {
    let quick = support::quick();
    let iters = if quick { 1 } else { 3 };
    let limit = if quick { 8 } else { usize::MAX };
    let thread_counts: &[usize] = if quick { &[1, 2, 4] } else { &[1, 2, 4, 8] };

    let mut entries: Vec<(String, f64, &'static str)> = Vec::new();
    let mut baseline = 0.0f64;
    for &threads in thread_counts {
        let report = serve_once(threads, iters, "eager", limit).expect("serve run");
        assert_eq!(
            report.errors, 0,
            "serve diverged from the single-thread reference: {:?}",
            report.failures
        );
        if threads == 1 {
            baseline = report.throughput;
        }
        println!(
            "[bench:serve] eager threads={:<2} case-runs={:<5} throughput={:>10.1} runs/s p50={:.3}ms p99={:.3}ms cache hits/misses={}/{}",
            threads,
            report.case_runs,
            report.throughput,
            report.p50_ms,
            report.p99_ms,
            report.module_cache_hits,
            report.module_cache_misses,
        );
        entries.push((format!("throughput_t{}", threads), report.throughput, "runs/s"));
        entries.push((format!("p50_t{}", threads), report.p50_ms, "ms"));
        entries.push((format!("p99_t{}", threads), report.p99_ms, "ms"));
        if threads > 1 && baseline > 0.0 {
            entries.push((
                format!("speedup_1_to_{}", threads),
                report.throughput / baseline,
                "x",
            ));
        }
    }

    // One async-wrapped point: the worker-pool hop under contention.
    let async_threads = 4;
    let report = serve_once(async_threads, iters, "async:eager", limit.min(16))
        .expect("async serve run");
    assert_eq!(report.errors, 0, "async serve diverged: {:?}", report.failures);
    println!(
        "[bench:serve] async:eager threads={} throughput={:.1} runs/s p99={:.3}ms",
        async_threads, report.throughput, report.p99_ms
    );
    entries.push((format!("async_throughput_t{}", async_threads), report.throughput, "runs/s"));

    let body: Vec<String> = entries
        .iter()
        .map(|(name, value, unit)| {
            format!(
                "    {{\"bench\": \"serve\", \"name\": \"{}\", \"value\": {:.3}, \"unit\": \"{}\"}}",
                name, value, unit
            )
        })
        .collect();
    let doc = format!(
        "{{\n  \"schema_version\": {},\n  \"entries\": [\n{}\n  ]\n}}\n",
        support::REPORT_SCHEMA_VERSION,
        body.join(",\n")
    );
    let path = out_path();
    match std::fs::write(&path, doc) {
        Ok(()) => println!("[bench:serve] wrote {} entries to {}", entries.len(), path),
        Err(e) => {
            eprintln!("[bench:serve] failed to write {}: {}", path, e);
            std::process::exit(1);
        }
    }
}
