//! Decompiler throughput: instructions/ms over the corpora, per tool —
//! the §Perf target for the decompilation hot path (depyf is meant for
//! interactive debugging sessions; decompiling a whole dump dir must be
//! instant).
//!
//! Run: `cargo bench --bench decompiler_speed` (merges into
//! `BENCH_hotpath.json`; `DEPYF_BENCH_QUICK=1` for smoke runs)

mod support;

use std::rc::Rc;
use std::time::Instant;

use depyf::bytecode::IsaVersion;
use depyf::corpus::syntax_cases;
use depyf::decompiler::baselines::all_tools_rc;
use depyf::decompiler::DecompilerTool;
use depyf::dynamo::{Dynamo, DynamoConfig};
use depyf::pylang::compile_module;
use depyf::vm::Vm;

fn main() {
    // Corpus of code objects: all syntax cases + generated code from a few
    // models.
    let mut codes = Vec::new();
    for c in syntax_cases() {
        let m = compile_module(c.source, "<b>", IsaVersion::V310).unwrap();
        codes.push(m.clone());
        codes.extend(m.nested_codes());
    }
    let model = "def f(x):\n    y = x * 2\n    print('mid')\n    if y.sum() >= 0:\n        y = y + 1\n    return y.sum()\nprint(f(torch.ones([4])).item())\n";
    let mut vm = Vm::new();
    let d = Dynamo::new(DynamoConfig::default());
    vm.eval_hook = Some(d.clone());
    vm.exec_source(model, IsaVersion::V310).unwrap();
    for (_, code) in d.generated_codes().iter() {
        codes.push(Rc::clone(code));
    }
    let total_instrs: usize = codes.iter().map(|c| c.instrs.len()).sum();
    let total_bytes: usize = codes.iter().map(|c| c.raw.len()).sum();
    println!("corpus: {} code objects, {} instructions, {} raw bytes\n", codes.len(), total_instrs, total_bytes);

    let mut rep = support::Reporter::new("decompiler_speed");
    for tool in all_tools_rc() {
        if tool.name() != "depyf" && tool.name() != "pycdc" {
            continue; // version-locked baselines can't decode V310
        }
        let iters = support::iters(20);
        let mut ok = 0usize;
        let t0 = Instant::now();
        for _ in 0..iters {
            ok = 0;
            for code in &codes {
                if tool.decompile(&Rc::clone(code)).is_ok() {
                    ok += 1;
                }
            }
        }
        let dt = t0.elapsed();
        let per_pass_ms = dt.as_secs_f64() * 1000.0 / iters as f64;
        println!(
            "{:<8} {:>8.2} ms/corpus-pass  {:>10.1} instrs/ms  ({} of {} decompiled)",
            tool.name(),
            per_pass_ms,
            (total_instrs * iters) as f64 / (dt.as_secs_f64() * 1000.0),
            ok,
            codes.len()
        );
        rep.record(&format!("{}_corpus_pass", tool.name()), per_pass_ms * 1e6, "ns/pass");
    }
    rep.finish();
}
