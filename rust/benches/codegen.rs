//! Codegen-backend benches: what compiling an [`ExecPlan`] into a flat
//! loop program buys over interpreting it.
//!
//! * `gelu_chain_*` — a pure elementwise gelu-residual chain (the
//!   register-allocation showcase: every intermediate lives in a reused
//!   slot, every op is a specialized inner loop). Acceptance gate:
//!   codegen `>= 1.5x` over the interpreted eager ExecPlan.
//! * `matmul_epilogue_*` — `gelu(x @ w + bias)`: the k-blocked matmul
//!   kernel with the bias/gelu epilogue fused into its output tiles.
//!   Acceptance gate: `>= 1.3x` over the interpreted plan.
//!
//! Both cases run the loop program single-threaded and with a 4-worker
//! row-tiling pool; every timed module is asserted bitwise-equal to the
//! eager oracle first. The interpreted baseline is the *unfused* eager
//! ExecPlan — the plain node-by-node interpreter the paper's workflow
//! starts from — with the fused interpreter recorded alongside for
//! context.
//!
//! Run: `cargo bench --bench codegen`. Merges into `BENCH_hotpath.json`
//! and additionally writes `BENCH_codegen.json` (override with
//! `DEPYF_BENCH_CODEGEN_OUT`); `DEPYF_BENCH_QUICK=1` for CI smoke runs,
//! which skip the flaky-on-shared-runners speedup gates.

mod support;

use std::rc::Rc;
use std::sync::Arc;

use depyf::api::{Backend, CompileRequest, CompiledModule, EagerBackend, OptLevel};
use depyf::backend::eager::EagerModule;
use depyf::codegen::CodegenBackend;
use depyf::graph::{Graph, OpKind};
use depyf::tensor::{Rng, Tensor};

fn out_path() -> String {
    std::env::var("DEPYF_BENCH_CODEGEN_OUT").unwrap_or_else(|_| "BENCH_codegen.json".into())
}

/// `blocks` of `y = gelu(x * c + bias) + x` — pure elementwise work.
fn gelu_chain(rows: usize, d: usize, blocks: usize) -> Graph {
    let mut g = Graph::new("codegen_gelu_chain");
    let x = g.placeholder("x", &[rows, d]);
    let mut cur = x;
    for i in 0..blocks {
        let c = g.const_scalar(0.5 + i as f64 * 0.01);
        let bias = g.const_tensor(Tensor::new(
            vec![d],
            (0..d).map(|j| (j as f32) * 0.003 - 0.2).collect(),
        ));
        let t = g.add_op(OpKind::Mul, vec![cur, c]).unwrap();
        let tb = g.add_op(OpKind::Add, vec![t, bias]).unwrap();
        let a = g.add_op(OpKind::Gelu, vec![tb]).unwrap();
        cur = g.add_op(OpKind::Add, vec![a, cur]).unwrap();
    }
    g.set_outputs(vec![cur]);
    g
}

/// `gelu(x @ w + bias)` — the matmul kernel plus a fusable epilogue.
fn matmul_epilogue(m: usize, k: usize, n: usize) -> Graph {
    let mut g = Graph::new("codegen_matmul_epilogue");
    let x = g.placeholder("x", &[m, k]);
    let mut rng = Rng::new(7);
    let w = g.const_tensor(Tensor::randn(&[k, n], &mut rng));
    let bias = g.const_tensor(Tensor::randn(&[n], &mut rng));
    let mm = g.add_op(OpKind::MatMul, vec![x, w]).unwrap();
    let b = g.add_op(OpKind::Add, vec![mm, bias]).unwrap();
    let ge = g.add_op(OpKind::Gelu, vec![b]).unwrap();
    g.set_outputs(vec![ge]);
    g
}

fn inputs_for(g: &Graph, seed: u64) -> Vec<Rc<Tensor>> {
    let mut rng = Rng::new(seed);
    g.input_shapes().into_iter().map(|(_, s)| Rc::new(Tensor::randn(&s, &mut rng))).collect()
}

fn assert_bitwise(tag: &str, oracle: &[Tensor], got: &[Tensor]) {
    assert_eq!(oracle.len(), got.len(), "{}: output arity diverged", tag);
    for (x, y) in oracle.iter().zip(got.iter()) {
        assert!(
            x.data().iter().zip(y.data().iter()).all(|(p, q)| p.to_bits() == q.to_bits()),
            "{}: codegen diverged bitwise from the eager oracle",
            tag
        );
    }
}

/// Time one case across the four executors; returns the gated speedup
/// (interpreted plan / best loop-program configuration).
fn bench_case(
    rep: &mut support::Reporter,
    entries: &mut Vec<(String, f64, &'static str)>,
    tag: &str,
    g: Graph,
    iters: usize,
    seed: u64,
) -> f64 {
    let g = Arc::new(g);
    let req = CompileRequest::new(&g.name.clone(), Arc::clone(&g)).with_opt_level(OptLevel::O2);
    let opt_graph = Arc::clone(&req.optimized().graph);
    let interp = EagerModule::with_fusion(Arc::clone(&opt_graph), "eager".into(), false);
    let fused = EagerBackend.compile(&req).expect("eager compile");
    let cg1 = CodegenBackend::new().compile(&req).expect("codegen compile");
    let cg4 = CodegenBackend::with_threads(4).compile(&req).expect("codegen compile (t4)");

    let inputs = inputs_for(&g, seed);
    let oracle = fused.call(&inputs).unwrap();
    assert_bitwise(tag, &oracle, &interp.call(&inputs).unwrap());
    assert_bitwise(tag, &oracle, &cg1.call(&inputs).unwrap());
    assert_bitwise(tag, &oracle, &cg4.call(&inputs).unwrap());

    let interp_ns = support::time_ns(iters, || {
        interp.call(&inputs).unwrap();
    });
    let fused_ns = support::time_ns(iters, || {
        fused.call(&inputs).unwrap();
    });
    let cg1_ns = support::time_ns(iters, || {
        cg1.call(&inputs).unwrap();
    });
    let cg4_ns = support::time_ns(iters, || {
        cg4.call(&inputs).unwrap();
    });

    let mut put = |name: String, value: f64, unit: &'static str| {
        rep.record(&name, value, unit);
        entries.push((name, value, unit));
    };
    put(format!("{}_interp_call", tag), interp_ns, "ns/call");
    put(format!("{}_fused_call", tag), fused_ns, "ns/call");
    put(format!("{}_codegen_t1_call", tag), cg1_ns, "ns/call");
    put(format!("{}_codegen_t4_call", tag), cg4_ns, "ns/call");
    let speedup = interp_ns / cg1_ns.min(cg4_ns);
    put(format!("{}_speedup", tag), speedup, "x");
    speedup
}

fn main() {
    let mut rep = support::Reporter::new("codegen");
    let mut entries: Vec<(String, f64, &'static str)> = Vec::new();
    let quick = support::quick();

    // Elementwise residual chain: 512x512 f32 (1 MiB live) x 6 blocks.
    // Large enough that the 4-thread row tiling engages (> 64 Ki
    // elements per loop), small enough to stay cache-resident per chunk.
    let elem = bench_case(
        &mut rep,
        &mut entries,
        "gelu_chain",
        gelu_chain(512, 512, 6),
        support::iters(30),
        1,
    );
    if !quick {
        assert!(
            elem >= 1.5,
            "acceptance: loop program must beat the interpreted plan >= 1.5x \
             on the elementwise chain (got {:.2}x)",
            elem
        );
    }

    // Matmul + fused epilogue: [256,256] @ [256,384] + bias -> gelu.
    // ~25M MACs/call, above the pool's minimum-work threshold.
    let mm = bench_case(
        &mut rep,
        &mut entries,
        "matmul_epilogue",
        matmul_epilogue(256, 256, 384),
        support::iters(20),
        2,
    );
    if !quick {
        assert!(
            mm >= 1.3,
            "acceptance: loop program must beat the interpreted plan >= 1.3x \
             on matmul+epilogue (got {:.2}x)",
            mm
        );
    }

    rep.finish();

    // The standalone report: same schema as BENCH_hotpath.json, one file
    // per subsystem so CI can gate on it without parsing the merged doc.
    let body: Vec<String> = entries
        .iter()
        .map(|(name, value, unit)| {
            format!(
                "    {{\"bench\": \"codegen\", \"name\": \"{}\", \"value\": {:.3}, \"unit\": \"{}\"}}",
                name, value, unit
            )
        })
        .collect();
    let doc = format!(
        "{{\n  \"schema_version\": {},\n  \"entries\": [\n{}\n  ]\n}}\n",
        support::REPORT_SCHEMA_VERSION,
        body.join(",\n")
    );
    let path = out_path();
    match std::fs::write(&path, doc) {
        Ok(()) => println!("[bench:codegen] wrote {} entries to {}", entries.len(), path),
        Err(e) => {
            eprintln!("[bench:codegen] failed to write {}: {}", path, e);
            std::process::exit(1);
        }
    }
}
