//! Custom-backend example — the `torch.compile(backend=my_compiler)`
//! workflow through `depyf::api`'s staged pipeline:
//!
//! 1. Implement [`Backend`]: `plan()` returns a declarative
//!    [`CompilePlan`] (here: the trivial single-partition plan) and
//!    `lower()` returns a [`CompiledModule`] (here: a counting wrapper
//!    over the eager reference executor that stamps its own
//!    `backend_name`).
//! 2. `register_backend(...)` — it becomes addressable by name everywhere
//!    a built-in is (`SessionBuilder::backend_named`, the CLI's
//!    `--backend` flag, next to `eager`, `xla`, `sharded`, `batched`).
//! 3. Drive a model through a session; captured graphs compile through the
//!    custom backend, and `finish()` indexes the dumps in `manifest.json`.
//!
//! Run: `cargo run --release --example custom_backend`

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use depyf::backend::eager::EagerModule;
use depyf::graph::Graph;
use depyf::prelude::*;

/// A user-written graph compiler: delegates execution to the eager
/// reference executor but counts compilations and tags its output.
/// Backends are `Send + Sync` (the registry is process-wide and serving
/// threads share them), so the counter is atomic, not a `Cell`.
struct CountingBackend {
    compiles: AtomicUsize,
}

impl Backend for CountingBackend {
    fn name(&self) -> &str {
        "counting"
    }

    fn plan(&self, req: &CompileRequest) -> Result<CompilePlan, DepyfError> {
        // The request carries everything a planner might inspect: the
        // graph, example-input specs, the guard context that specialized
        // it, and the content-hash cache key.
        println!(
            "[counting] planning {}: {} ops, inputs {:?}, {} guards, key {:016x}",
            req.name,
            req.graph.num_ops(),
            req.input_specs.iter().map(|s| s.shape.clone()).collect::<Vec<_>>(),
            req.guards.len(),
            req.cache_key
        );
        Ok(CompilePlan::monolithic("counting", req, "eager"))
    }

    fn lower(&self, req: &CompileRequest, plan: &CompilePlan) -> Result<Arc<dyn CompiledModule>, DepyfError> {
        let n = self.compiles.fetch_add(1, Ordering::Relaxed) + 1;
        println!(
            "[counting] lowering {} (partition 0 targets '{}'), compile #{}",
            req.name, plan.partitions[0].target, n
        );
        Ok(Arc::new(EagerModule::with_name(
            Arc::clone(&req.graph),
            format!("counting#{}", n),
        )))
    }
}

const MODEL: &str = "\
def f(x, y):
    return ((x @ y) + 1).relu().sum()
a = torch.ones([4, 4])
b = torch.ones([4, 4])
print('f =', f(a, b).item())
print('f =', f(a, b).item())
";

fn main() -> Result<(), DepyfError> {
    let backend = Arc::new(CountingBackend { compiles: AtomicUsize::new(0) });
    register_backend(backend.clone());
    println!("registered backends: {}", depyf::api::backend_names().join(", "));

    let dir = std::env::temp_dir().join("depyf_custom_backend");
    let _ = std::fs::remove_dir_all(&dir);
    let mut session = Session::builder()
        .dump_to(&dir)
        .backend_named("counting")
        .fallback(FallbackPolicy::Error) // a custom backend bug should surface, not degrade
        .build()?;
    session.run_source("main", MODEL)?;
    print!("{}", session.vm.take_output());

    // The installed compiled-graph global carries the custom backend tag.
    let compiled = session.vm.get_global("__compiled_fn_1").expect("graph installed");
    if let Value::CompiledGraph(g) = &compiled {
        println!("installed {:?}", g);
        assert!(g.backend_name.starts_with("counting#"), "{}", g.backend_name);
        assert_eq!(g.module.stats().partitions, 1);
    }
    assert_eq!(backend.compiles.load(Ordering::Relaxed), 1, "second call must hit the dynamo cache");

    // The same graph, planned standalone: plans are plain data.
    let g: Arc<Graph> = Arc::clone(&session.dynamo.graphs()[0].1);
    let req = CompileRequest::new("__compiled_fn_1", g);
    let plan = backend.plan(&req)?;
    println!("\n--- CompilePlan (round-trips through JSON) ---\n{}", plan.to_json());
    assert_eq!(CompilePlan::parse(&plan.to_json())?, plan);

    let artifacts = session.finish()?;
    println!("dumped {} artifacts into {}:", artifacts.len(), dir.display());
    for a in &artifacts {
        println!("  [{:>18}] {}", a.kind.as_str(), a.file_name());
    }
    println!("\n--- manifest.json ---\n{}", std::fs::read_to_string(dir.join("manifest.json"))?);
    println!("custom_backend OK");
    Ok(())
}
