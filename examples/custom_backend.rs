//! Custom-backend example — the `torch.compile(backend=my_compiler)`
//! workflow through `depyf::api`:
//!
//! 1. Implement [`Backend`] (here: a counting wrapper over the eager
//!    reference executor that stamps its own `backend_name`).
//! 2. `register_backend(...)` — it becomes addressable by name everywhere
//!    a built-in is (`SessionBuilder::backend_named`, the CLI's
//!    `--backend` flag).
//! 3. Drive a model through a session; captured graphs compile through the
//!    custom backend, and `finish()` indexes the dumps in `manifest.json`.
//!
//! Run: `cargo run --release --example custom_backend`

use std::cell::Cell;
use std::rc::Rc;

use depyf::api::eager_graph_fn;
use depyf::graph::{CompiledGraphFn, Graph};
use depyf::prelude::*;

/// A user-written graph compiler: delegates execution to the eager
/// reference executor but counts compilations and tags its output.
struct CountingBackend {
    compiles: Cell<usize>,
}

impl Backend for CountingBackend {
    fn name(&self) -> &str {
        "counting"
    }

    fn compile(&self, name: &str, graph: Rc<Graph>, _ctx: &CompileCtx) -> Result<CompiledGraphFn, DepyfError> {
        self.compiles.set(self.compiles.get() + 1);
        println!("[counting] compile #{}: {} ({} ops)", self.compiles.get(), name, graph.num_ops());
        Ok(eager_graph_fn(name, graph, format!("counting#{}", self.compiles.get())))
    }
}

const MODEL: &str = "\
def f(x, y):
    return ((x @ y) + 1).relu().sum()
a = torch.ones([4, 4])
b = torch.ones([4, 4])
print('f =', f(a, b).item())
print('f =', f(a, b).item())
";

fn main() -> Result<(), DepyfError> {
    let backend = Rc::new(CountingBackend { compiles: Cell::new(0) });
    register_backend(backend.clone());
    println!("registered backends: {}", depyf::api::backend_names().join(", "));

    let dir = std::env::temp_dir().join("depyf_custom_backend");
    let _ = std::fs::remove_dir_all(&dir);
    let mut session = Session::builder()
        .dump_to(&dir)
        .backend_named("counting")
        .fallback(FallbackPolicy::Error) // a custom backend bug should surface, not degrade
        .build()?;
    session.run_source("main", MODEL)?;
    print!("{}", session.vm.take_output());

    // The installed compiled-graph global carries the custom backend tag.
    let compiled = session.vm.get_global("__compiled_fn_1").expect("graph installed");
    if let Value::CompiledGraph(g) = &compiled {
        println!("installed {:?}", g);
        assert!(g.backend_name.starts_with("counting#"), "{}", g.backend_name);
    }
    assert_eq!(backend.compiles.get(), 1, "second call must hit the dynamo cache");

    let artifacts = session.finish()?;
    println!("\ndumped {} artifacts into {}:", artifacts.len(), dir.display());
    for a in &artifacts {
        println!("  [{:>18}] {}", a.kind.as_str(), a.file_name());
    }
    println!("\n--- manifest.json ---\n{}", std::fs::read_to_string(dir.join("manifest.json"))?);
    println!("custom_backend OK");
    Ok(())
}
