//! Quickstart — the paper's Figure 2 workflow through the `depyf::api`
//! session builder:
//!
//! 1. `Session::builder().dump_to(dir).build()?`: run a model under the
//!    compiler and dump everything it did (`full_code.py`,
//!    `__compiled_fn_*.py`, `__transformed_*.py`, disassembly) as typed
//!    artifacts indexed by `manifest.json`.
//! 2. `.trace(TraceMode::StepGraphs)`: set a breakpoint inside a compiled
//!    graph's dumped source and step through it line by line, inspecting
//!    intermediate tensors.
//!
//! Run: `cargo run --release --example quickstart`

use depyf::prelude::*;

const MODEL: &str = "\
torch.manual_seed(0)
W1 = torch.randn([8, 16])
W2 = torch.randn([16, 4])
def forward(x):
    h = (x @ W1).relu()
    return (h @ W2).softmax()
x = torch.randn([2, 8])
print('out sum:', forward(x).sum().item())
print('out sum:', forward(x).sum().item())
";

fn main() -> Result<(), DepyfError> {
    let dir = std::env::temp_dir().join("depyf_quickstart");
    let _ = std::fs::remove_dir_all(&dir);

    // ---- with depyf.prepare_debug(dir): ----
    println!("== prepare_debug: capture + dump ==");
    let mut session = Session::builder().dump_to(&dir).backend_named("eager").build()?;
    session.run_source("main", MODEL)?;
    println!("{}", session.vm.take_output());
    println!("compiler metrics: {}", session.dynamo.metrics.report());
    let artifacts = session.finish()?;
    println!("\ndumped {} artifacts into {} (indexed by manifest.json):", artifacts.len(), dir.display());
    for a in &artifacts {
        println!("  [{:>18}] {}", a.kind.as_str(), a.file_name());
    }
    let compiled = std::fs::read_to_string(dir.join("__compiled_fn_1.py"))?;
    println!("\n--- __compiled_fn_1.py (the captured graph) ---\n{}", compiled);
    let transformed = artifacts
        .iter()
        .find(|a| a.kind == ArtifactKind::TransformedSource)
        .expect("transformed source dumped");
    println!(
        "--- {} (decompiled transformed bytecode of '{}') ---\n{}",
        transformed.file_name(),
        transformed.name,
        std::fs::read_to_string(&transformed.path)?
    );

    // ---- with depyf.debug(): ----
    println!("== debug: step through the compiled graph ==");
    let dir2 = std::env::temp_dir().join("depyf_quickstart_dbg");
    let _ = std::fs::remove_dir_all(&dir2);
    let mut dbg_session = Session::builder().dump_to(&dir2).trace(TraceMode::StepGraphs).build()?;
    // Break on line 3 of the compiled graph (the second op).
    dbg_session.debugger.break_at("__compiled_fn_1.py", 3);
    dbg_session.run_source("main", MODEL)?;
    dbg_session.finish()?;
    for ev in dbg_session.debugger.events() {
        println!(
            "breakpoint hit: {}:{} in {} -> {}",
            std::path::Path::new(&ev.file).file_name().unwrap().to_string_lossy(),
            ev.line,
            ev.func,
            ev.locals.iter().map(|(k, v)| format!("{}={}", k, v)).collect::<Vec<_>>().join(", ")
        );
    }
    println!("\nquickstart OK");
    Ok(())
}
