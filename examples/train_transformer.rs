//! End-to-end driver: train the Layer-2 transformer LM through the full
//! three-layer stack — JAX+Pallas AOT artifacts (built by `make artifacts`)
//! loaded and executed by the Rust PJRT runtime; Python never runs here.
//!
//! Trains ~100k parameters for a few hundred steps on a synthetic
//! next-token corpus and logs the loss curve (recorded in EXPERIMENTS.md).
//!
//! Run: `make artifacts && cargo run --release --example train_transformer`

use depyf::runtime::{Arg, Runtime};
use depyf::tensor::{Rng, Tensor};
use depyf::DepyfError;

const VOCAB: usize = 128;
const SEQ: usize = 32;
const BATCH: usize = 8;
const STEPS: usize = 300;

/// Synthetic corpus: an affine token recurrence with noise — learnable
/// structure for a tiny LM.
fn make_batch(rng: &mut Rng) -> (Tensor, Tensor) {
    let mut toks = Vec::with_capacity(BATCH * SEQ);
    for _ in 0..BATCH {
        let mut t = rng.below(VOCAB) as u64;
        for _ in 0..SEQ {
            toks.push(t as f32);
            // tok[i+1] = 7*tok[i] + 3 (mod V), with occasional noise
            t = if rng.below(10) == 0 { rng.below(VOCAB) as u64 } else { (7 * t + 3) % VOCAB as u64 };
        }
    }
    let tokens = Tensor::new(vec![BATCH, SEQ], toks);
    // next-token targets (shift left; final target follows the recurrence)
    let mut tgt = Vec::with_capacity(BATCH * SEQ);
    for b in 0..BATCH {
        for s in 0..SEQ {
            let v = if s + 1 < SEQ {
                tokens.data()[b * SEQ + s + 1]
            } else {
                ((7 * tokens.data()[b * SEQ + s] as u64 + 3) % VOCAB as u64) as f32
            };
            tgt.push(v);
        }
    }
    (tokens, Tensor::new(vec![BATCH, SEQ], tgt))
}

fn main() -> Result<(), DepyfError> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string());
    let rt = Runtime::cpu_with_artifacts(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    let names = rt.manifest().map(|m| m.names().join(", ")).unwrap_or_default();
    println!("artifacts: {}", names);

    // 1. Initialize parameters via the AOT init graph (constants baked from
    //    the jax PRNG — bit-identical to what python/tests validated).
    let (init_exe, init_art) = rt.load_artifact("init_params")?;
    let params: Vec<Tensor> = rt.execute(&init_exe, &[])?;
    let n_params: usize = params.iter().map(|p| p.numel()).sum();
    println!("initialized {} tensors, {} parameters", params.len(), n_params);
    assert_eq!(params.len(), init_art.n_outputs);

    // 2. Golden cross-check: first-step loss on the fixed batch must match
    //    what jax computed at artifact-build time.
    let (step_exe, _) = rt.load_artifact("train_step")?;
    let golden = std::fs::read_to_string(format!("{}/goldens/first_step_loss.txt", dir)).ok();
    let tok_text = std::fs::read_to_string(format!("{}/goldens/first_batch_tokens.txt", dir)).ok();
    if let (Some(golden), Some(tok_text)) = (golden, tok_text) {
        let toks: Vec<f32> = tok_text.split_whitespace().filter_map(|v| v.parse().ok()).collect();
        let tokens = Tensor::new(vec![BATCH, SEQ], toks);
        // np.roll(tokens, -1, axis=1)
        let mut tgt = vec![0f32; BATCH * SEQ];
        for b in 0..BATCH {
            for s in 0..SEQ {
                tgt[b * SEQ + s] = tokens.data()[b * SEQ + (s + 1) % SEQ];
            }
        }
        let targets = Tensor::new(vec![BATCH, SEQ], tgt);
        let mut args: Vec<Arg> = vec![Arg::I32(&tokens), Arg::I32(&targets)];
        for p in &params {
            args.push(Arg::F32(p));
        }
        let out = rt.execute_args(&step_exe, &args)?;
        let loss0 = out[0].item();
        let expected: f32 =
            golden.trim().parse().map_err(|e| DepyfError::Parse(format!("golden parse: {}", e)))?;
        let diff = (loss0 - expected).abs();
        println!("golden check: rust-PJRT loss {:.6} vs jax {:.6} (|d|={:.2e})", loss0, expected, diff);
        assert!(diff < 1e-3, "golden mismatch");
    }

    // 3. Train.
    let mut params = params;
    let mut rng = Rng::new(42);
    let t0 = std::time::Instant::now();
    let mut first = None;
    let mut last = 0.0f32;
    for step in 0..STEPS {
        let (tokens, targets) = make_batch(&mut rng);
        let mut args: Vec<Arg> = vec![Arg::I32(&tokens), Arg::I32(&targets)];
        for p in &params {
            args.push(Arg::F32(p));
        }
        let mut out = rt.execute_args(&step_exe, &args)?;
        let loss = out.remove(0).item();
        params = out;
        if first.is_none() {
            first = Some(loss);
        }
        last = loss;
        if step % 25 == 0 || step == STEPS - 1 {
            println!("step {:>4}  loss {:.4}", step, loss);
        }
        assert!(loss.is_finite(), "loss diverged at step {}", step);
    }
    let dt = t0.elapsed();
    let first = first.unwrap();
    println!(
        "trained {} steps in {:.1?} ({:.1} ms/step); loss {:.4} -> {:.4} (ln V = {:.4})",
        STEPS,
        dt,
        dt.as_millis() as f64 / STEPS as f64,
        first,
        last,
        (VOCAB as f32).ln()
    );
    assert!(last < first * 0.7, "loss did not decrease enough: {} -> {}", first, last);
    println!("train_transformer OK");
    Ok(())
}
